//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the request path.
//!
//! Python never runs here — `make artifacts` happened at build time; this
//! module turns `artifacts/*.hlo.txt` into compiled PJRT executables via
//! the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `compile` → `execute`), with input/output marshalling matching the
//! signatures in `artifacts/manifest.txt`.
//!
//! One [`WindowEngine`] wraps one compiled model variant; engines are
//! `Send` but not `Sync` (PJRT buffers are single-threaded here), so the
//! coordinator gives each engine to a dedicated worker thread.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context};

use crate::config::ConfigFile;
use crate::params::{CHANNELS, DIM, FRAMES_PER_PREDICTION, NUM_CLASSES};

pub mod engine_pool;

/// Parsed `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub frames: usize,
    pub channels: usize,
    pub dim: usize,
    pub num_classes: usize,
    pub im_seed: u64,
    pub im_digest: u64,
    pub sparse_window: String,
    pub dense_window: String,
}

fn parse_hex_or_dec(s: &str) -> crate::Result<u64> {
    let s = s.trim();
    if let Some(hex) = s.strip_prefix("0x") {
        Ok(u64::from_str_radix(hex, 16)?)
    } else {
        Ok(s.parse()?)
    }
}

impl Manifest {
    pub fn load(dir: &Path) -> crate::Result<Manifest> {
        let path = dir.join("manifest.txt");
        let file = ConfigFile::load(&path)?;
        let get = |k: &str| -> crate::Result<&str> {
            file.get(k)
                .with_context(|| format!("manifest missing key {k}"))
        };
        Ok(Manifest {
            frames: get("frames")?.parse()?,
            channels: get("channels")?.parse()?,
            dim: get("dim")?.parse()?,
            num_classes: get("num_classes")?.parse()?,
            im_seed: parse_hex_or_dec(get("im_seed")?)?,
            im_digest: parse_hex_or_dec(get("im_digest")?)?,
            sparse_window: get("sparse_window")?.to_string(),
            dense_window: get("dense_window")?.to_string(),
        })
    }

    /// Check the artifact was built for this binary's architecture
    /// constants and item-memory generator.
    pub fn validate(&self) -> crate::Result<()> {
        ensure!(self.channels == CHANNELS, "manifest channels {}", self.channels);
        ensure!(self.dim == DIM, "manifest dim {}", self.dim);
        ensure!(self.num_classes == NUM_CLASSES, "manifest classes {}", self.num_classes);
        ensure!(
            self.frames == FRAMES_PER_PREDICTION,
            "manifest frames {}",
            self.frames
        );
        let rust_digest = crate::hdc::im::ItemMemory::generate(self.im_seed).digest();
        ensure!(
            rust_digest == self.im_digest,
            "item-memory digest mismatch: rust {rust_digest:#018x} vs artifact {:#018x} — \
             rebuild artifacts (`make artifacts`)",
            self.im_digest
        );
        Ok(())
    }
}

/// Which compiled model a [`WindowEngine`] wraps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// (codes, am, threshold) → (scores, query)
    SparseWindow,
    /// (codes, am) → (scores, query)
    DenseWindow,
}

/// Result of one prediction-window execution.
#[derive(Clone, Debug)]
pub struct WindowOutput {
    pub scores: [i32; NUM_CLASSES],
    pub query: Vec<i32>,
}

impl WindowOutput {
    pub fn is_ictal(&self) -> bool {
        self.scores[crate::params::CLASS_ICTAL] > self.scores[crate::params::CLASS_INTERICTAL]
    }

    pub fn margin(&self) -> i64 {
        self.scores[crate::params::CLASS_ICTAL] as i64
            - self.scores[crate::params::CLASS_INTERICTAL] as i64
    }
}

/// A compiled, ready-to-execute prediction-window model.
///
/// The item-memory tables are *inputs* of the HLO (large constants do not
/// survive the HLO-text interchange — the printer elides them); the
/// engine regenerates them from [`crate::hdc::im`] at load time (the
/// manifest digest guarantees bit-equality with the Python side) and
/// binds them on every call.
pub struct WindowEngine {
    exe: xla::PjRtLoadedExecutable,
    /// Pre-built table literals, in artifact parameter order (between
    /// `codes` and `am`).
    tables: Vec<xla::Literal>,
    pub kind: EngineKind,
    pub frames: usize,
    pub path: PathBuf,
}

/// Flattened sparse tables: (im_pos i32[CH,CODES,SEG], elec i32[CH,SEG]).
fn sparse_table_literals(seed: u64) -> crate::Result<Vec<xla::Literal>> {
    use crate::params::{LBP_CODES, SEGMENTS};
    let im = crate::hdc::im::ItemMemory::generate(seed);
    let mut impos = Vec::with_capacity(CHANNELS * LBP_CODES * SEGMENTS);
    for c in 0..CHANNELS {
        for k in 0..LBP_CODES {
            let pos = im.lookup(c, k as u8);
            impos.extend(pos.pos.iter().map(|&p| p as i32));
        }
    }
    let mut elec = Vec::with_capacity(CHANNELS * SEGMENTS);
    for c in 0..CHANNELS {
        elec.extend(im.electrode(c).pos.iter().map(|&p| p as i32));
    }
    let impos_lit = xla::Literal::vec1(&impos)
        .reshape(&[CHANNELS as i64, LBP_CODES as i64, SEGMENTS as i64])
        .map_err(|e| anyhow::anyhow!("reshape im_pos: {e}"))?;
    let elec_lit = xla::Literal::vec1(&elec)
        .reshape(&[CHANNELS as i64, SEGMENTS as i64])
        .map_err(|e| anyhow::anyhow!("reshape elec_pos: {e}"))?;
    Ok(vec![impos_lit, elec_lit])
}

/// Flattened dense tables: (im_bits, elec_bits, tie_s, tie_t).
fn dense_table_literals(seed: u64) -> crate::Result<Vec<xla::Literal>> {
    use crate::params::LBP_CODES;
    let im = crate::hdc::im::DenseItemMemory::generate(seed);
    let mut im_bits = Vec::with_capacity(LBP_CODES * DIM);
    for k in 0..LBP_CODES {
        im_bits.extend(im.lookup(k as u8).to_i32s());
    }
    let mut elec_bits = Vec::with_capacity(CHANNELS * DIM);
    for c in 0..CHANNELS {
        elec_bits.extend(im.electrode(c).to_i32s());
    }
    let tie_s = im.tiebreak(0).to_i32s();
    let tie_t = im.tiebreak(1).to_i32s();
    Ok(vec![
        xla::Literal::vec1(&im_bits)
            .reshape(&[LBP_CODES as i64, DIM as i64])
            .map_err(|e| anyhow::anyhow!("reshape im_bits: {e}"))?,
        xla::Literal::vec1(&elec_bits)
            .reshape(&[CHANNELS as i64, DIM as i64])
            .map_err(|e| anyhow::anyhow!("reshape elec_bits: {e}"))?,
        xla::Literal::vec1(&tie_s),
        xla::Literal::vec1(&tie_t),
    ])
}

impl WindowEngine {
    /// Load + compile one HLO-text artifact and build its table inputs.
    pub fn load(
        client: &xla::PjRtClient,
        path: &Path,
        kind: EngineKind,
        frames: usize,
        seed: u64,
    ) -> crate::Result<WindowEngine> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))?;
        let tables = match kind {
            EngineKind::SparseWindow => sparse_table_literals(seed)?,
            EngineKind::DenseWindow => dense_table_literals(seed)?,
        };
        Ok(WindowEngine {
            exe,
            tables,
            kind,
            frames,
            path: path.to_path_buf(),
        })
    }

    /// Execute one window.
    ///
    /// `codes`: frame-major `[frames][CHANNELS]` LBP codes;
    /// `am`: `[NUM_CLASSES * DIM]` 0/1 plane; `threshold`: temporal
    /// thinning threshold (ignored by the dense model).
    pub fn run(&self, codes: &[u8], am: &[i32], threshold: i32) -> crate::Result<WindowOutput> {
        ensure!(
            codes.len() == self.frames * CHANNELS,
            "codes length {} != {}",
            codes.len(),
            self.frames * CHANNELS
        );
        ensure!(am.len() == NUM_CLASSES * DIM, "am length {}", am.len());

        let codes_i32: Vec<i32> = codes.iter().map(|&c| c as i32).collect();
        let codes_lit = xla::Literal::vec1(&codes_i32)
            .reshape(&[self.frames as i64, CHANNELS as i64])
            .map_err(|e| anyhow::anyhow!("reshape codes: {e}"))?;
        let am_lit = xla::Literal::vec1(am)
            .reshape(&[NUM_CLASSES as i64, DIM as i64])
            .map_err(|e| anyhow::anyhow!("reshape am: {e}"))?;

        // Parameter order (see aot.py): codes, <tables…>, am [, thr].
        let thr_lit = xla::Literal::vec1(&[threshold]);
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 + self.tables.len());
        args.push(&codes_lit);
        match self.kind {
            EngineKind::SparseWindow => {
                args.extend(self.tables.iter());
                args.push(&am_lit);
                args.push(&thr_lit);
            }
            EngineKind::DenseWindow => {
                args.extend(self.tables.iter());
                args.push(&am_lit);
            }
        }
        let result = self
            .exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("execute {}: {e}", self.path.display()))?;

        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
        // aot.py lowers with return_tuple=True → (scores, query).
        let (scores_lit, query_lit) = out
            .to_tuple2()
            .map_err(|e| anyhow::anyhow!("untuple result: {e}"))?;
        let scores_vec = scores_lit
            .to_vec::<i32>()
            .map_err(|e| anyhow::anyhow!("scores: {e}"))?;
        let query = query_lit
            .to_vec::<i32>()
            .map_err(|e| anyhow::anyhow!("query: {e}"))?;
        ensure!(scores_vec.len() == NUM_CLASSES, "scores len {}", scores_vec.len());
        ensure!(query.len() == DIM, "query len {}", query.len());
        Ok(WindowOutput {
            scores: [scores_vec[0], scores_vec[1]],
            query,
        })
    }
}

/// The PJRT runtime: one CPU client + the artifact manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client and validate the artifacts in `dir`.
    pub fn new(dir: &Path) -> crate::Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        manifest.validate()?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            dir: dir.to_path_buf(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn load_sparse(&self) -> crate::Result<WindowEngine> {
        WindowEngine::load(
            &self.client,
            &self.dir.join(&self.manifest.sparse_window),
            EngineKind::SparseWindow,
            self.manifest.frames,
            self.manifest.im_seed,
        )
    }

    pub fn load_dense(&self) -> crate::Result<WindowEngine> {
        WindowEngine::load(
            &self.client,
            &self.dir.join(&self.manifest.dense_window),
            EngineKind::DenseWindow,
            self.manifest.frames,
            self.manifest.im_seed,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let text = "\
# comment
frames = 256
channels = 64
dim = 1024
segments = 8
num_classes = 2
im_seed = 0x5eed1ee600000001
im_digest = 0xf7cdf969f2b33a13
sparse_window = sparse_window.hlo.txt
dense_window = dense_window.hlo.txt
";
        let dir = std::env::temp_dir().join(format!("hdc_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.frames, 256);
        assert_eq!(m.im_seed, crate::params::IM_SEED);
        m.validate().expect("digest must match the rust generator");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_digest_mismatch_rejected() {
        let text = "\
frames = 256
channels = 64
dim = 1024
num_classes = 2
im_seed = 0x5eed1ee600000001
im_digest = 0xdeadbeefdeadbeef
sparse_window = s.hlo.txt
dense_window = d.hlo.txt
";
        let dir = std::env::temp_dir().join(format!("hdc_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.validate().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
