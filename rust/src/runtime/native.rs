//! Native window engine: the bit-accurate golden model behind the same
//! `(codes, am, threshold) →` [`WindowOutput`] contract as the PJRT
//! engine, so the coordinator's serving path is fully exercisable in the
//! default (dependency-free) build — no artifacts, no `xla`.
//!
//! Semantics mirror the HLO models exactly (`cross_language.rs` pins the
//! PJRT engine against the same golden model):
//!
//! * **sparse**: CompIM bind → OR bundling → 256-frame temporal counters →
//!   thinning at the *per-window* threshold → AND-popcount scores against
//!   the AM plane (packed popcount — 64 word ops per class instead of
//!   1024 multiplies, §Perf L3-3);
//! * **dense**: XOR bind → majority bundling → temporal majority →
//!   `DIM - hamming` scores (normalised "bigger = more similar").
//!
//! The native unit of work is a **batch** of N windows
//! ([`NativeWindowEngine::run_batch`]): the decoded AM ([`AmPlane`]) is
//! held once, every window is encoded, and all queries stream through one
//! [`crate::hdc::am::AssociativeMemory::search_batch`] call.
//! [`NativeWindowEngine::run`] is the N=1 degenerate case and delegates
//! to a batch of one.

use crate::ensure;
use crate::hdc::am::{AmPlane, Metric};
use crate::hdc::classifier::{
    ClassifierConfig, DenseEncoder, Encoder, Frame, SparseEncoder, Variant,
};
use crate::hdc::hv::Hv;
use crate::params::{CHANNELS, FRAMES_PER_PREDICTION};

use super::{EngineKind, WindowOutput};

/// Frame-major LBP codes of one full prediction window.
pub const WINDOW_CODES: usize = FRAMES_PER_PREDICTION * CHANNELS;

/// One native engine wrapping a streaming encoder of the requested kind.
///
/// Mutable because the encoder carries window state; the engine pool gives
/// each engine to a dedicated worker thread, exactly like the PJRT one.
pub struct NativeWindowEngine {
    kind: EngineKind,
    encoder: EncoderSlot,
}

enum EncoderSlot {
    Sparse(Box<SparseEncoder>),
    Dense(Box<DenseEncoder>),
}

impl NativeWindowEngine {
    pub fn new(kind: EngineKind, cfg: ClassifierConfig) -> NativeWindowEngine {
        let encoder = match kind {
            EngineKind::SparseWindow => {
                EncoderSlot::Sparse(Box::new(SparseEncoder::new(Variant::Optimized, cfg)))
            }
            EngineKind::DenseWindow => EncoderSlot::Dense(Box::new(DenseEncoder::new(cfg))),
        };
        NativeWindowEngine { kind, encoder }
    }

    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    /// Execute one window. Same contract as the PJRT engine's `run`:
    /// `codes` is one full frame-major window, `am` the
    /// `[NUM_CLASSES * DIM]` 0/1 plane, `threshold` the temporal thinning
    /// threshold (ignored by the dense model). Delegates to
    /// [`Self::run_batch`] with a batch of one, so the serial and batched
    /// paths cannot drift.
    pub fn run(&mut self, codes: &[u8], am: &[i32], threshold: i32) -> crate::Result<WindowOutput> {
        let plane = AmPlane::from_i32s(am)?;
        let mut outputs = self.run_batch(codes, &plane, &[threshold])?;
        Ok(outputs.pop().expect("a batch of one yields one output"))
    }

    /// Execute a batch of `thresholds.len()` windows against one AM.
    ///
    /// `codes` is `N` frame-major windows concatenated
    /// (`N * FRAMES_PER_PREDICTION * CHANNELS` bytes); `thresholds` holds
    /// one temporal thinning threshold per window (ignored by the dense
    /// model, which still uses its length as the batch size). The decoded
    /// AM is read from the [`AmPlane`] — shared across jobs of one
    /// session, it is decoded at most once — and all N queries are scored
    /// through one [`crate::hdc::am::AssociativeMemory::search_batch`]
    /// pass. An empty batch returns an empty vec.
    pub fn run_batch(
        &mut self,
        codes: &[u8],
        am: &AmPlane,
        thresholds: &[i32],
    ) -> crate::Result<Vec<WindowOutput>> {
        let n = thresholds.len();
        ensure!(
            codes.len() == n * WINDOW_CODES,
            "codes length {} != {} ({} windows of {})",
            codes.len(),
            n * WINDOW_CODES,
            n,
            WINDOW_CODES
        );

        let (queries, metric) = match &mut self.encoder {
            EncoderSlot::Sparse(enc) => {
                // The dense model ignores thresholds (PJRT contract), so
                // only the sparse path range-checks them — all of them,
                // before any window is encoded, so a bad batch is
                // rejected atomically.
                for &threshold in thresholds {
                    ensure!(
                        (0..=u16::MAX as i32).contains(&threshold),
                        "threshold {threshold} out of range"
                    );
                }
                let mut queries = Vec::with_capacity(n);
                for (chunk, &threshold) in codes.chunks_exact(WINDOW_CODES).zip(thresholds) {
                    enc.set_temporal_threshold(threshold as u16);
                    queries.push(encode_window(enc.as_mut(), chunk));
                }
                (queries, Metric::Overlap)
            }
            EncoderSlot::Dense(enc) => {
                let queries = codes
                    .chunks_exact(WINDOW_CODES)
                    .map(|chunk| encode_window(enc.as_mut(), chunk))
                    .collect();
                (queries, Metric::Hamming)
            }
        };

        let results = am.memory().search_batch(&queries, metric);
        Ok(queries
            .iter()
            .zip(results)
            .map(|(query, r)| WindowOutput {
                scores: [r.scores[0] as i32, r.scores[1] as i32],
                query: query.to_i32s(),
            })
            .collect())
    }
}

/// Drive one full window through a streaming encoder.
fn encode_window(enc: &mut dyn Encoder, codes: &[u8]) -> Hv {
    enc.reset();
    let mut frame = [0u8; CHANNELS];
    let mut query = None;
    for chunk in codes.chunks_exact(CHANNELS) {
        frame.copy_from_slice(chunk);
        let f: Frame = frame;
        if let Some(q) = enc.push_frame(&f) {
            query = Some(q);
        }
    }
    // codes length was validated to exactly one window.
    query.expect("one full window emits exactly one query")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::am::AssociativeMemory;
    use crate::params::{DIM, LBP_CODES, NUM_CLASSES};
    use crate::rng::Xoshiro256;

    fn random_codes(rng: &mut Xoshiro256) -> Vec<u8> {
        (0..FRAMES_PER_PREDICTION * CHANNELS)
            .map(|_| rng.next_below(LBP_CODES as u64) as u8)
            .collect()
    }

    #[test]
    fn sparse_engine_matches_inline_golden_model() {
        let mut rng = Xoshiro256::new(0xBEEF);
        let codes = random_codes(&mut rng);
        let am = AssociativeMemory::new(Hv::random(&mut rng, 0.3), Hv::random(&mut rng, 0.3));
        let threshold = 90u16;

        let cfg = ClassifierConfig {
            temporal_threshold: threshold,
            ..ClassifierConfig::optimized()
        };
        let mut enc = SparseEncoder::new(Variant::Optimized, cfg);
        let query = encode_window(&mut enc, &codes);
        let expect_scores = [
            query.overlap(&am.classes[0]) as i32,
            query.overlap(&am.classes[1]) as i32,
        ];

        let mut engine =
            NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
        let out = engine.run(&codes, &am.to_i32s(), threshold as i32).unwrap();
        assert_eq!(out.query, query.to_i32s());
        assert_eq!(out.scores, expect_scores);
    }

    #[test]
    fn per_job_threshold_is_honoured() {
        // The PJRT engine takes the threshold per call; the native engine
        // must too (a session's tuned threshold rides on the Job).
        let mut rng = Xoshiro256::new(0xCAFE);
        let codes = random_codes(&mut rng);
        let am = vec![0i32; NUM_CLASSES * DIM];
        let mut engine =
            NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
        let loose = engine.run(&codes, &am, 40).unwrap();
        let tight = engine.run(&codes, &am, 200).unwrap();
        let ones = |q: &[i32]| q.iter().filter(|&&b| b != 0).count();
        assert!(
            ones(&loose.query) > ones(&tight.query),
            "lower threshold must yield a denser query ({} vs {})",
            ones(&loose.query),
            ones(&tight.query)
        );
    }

    #[test]
    fn dense_engine_scores_are_normalised_hamming() {
        let mut rng = Xoshiro256::new(0xD0D0);
        let codes = random_codes(&mut rng);
        let am = AssociativeMemory::new(Hv::random_half(&mut rng), Hv::random_half(&mut rng));

        let mut enc = DenseEncoder::new(ClassifierConfig::default());
        let query = encode_window(&mut enc, &codes);
        let expect_scores = [
            DIM as i32 - query.hamming(&am.classes[0]) as i32,
            DIM as i32 - query.hamming(&am.classes[1]) as i32,
        ];

        let mut engine =
            NativeWindowEngine::new(EngineKind::DenseWindow, ClassifierConfig::default());
        let out = engine.run(&codes, &am.to_i32s(), 0).unwrap();
        assert_eq!(out.query, query.to_i32s());
        assert_eq!(out.scores, expect_scores);
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut engine =
            NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
        let am = vec![0i32; NUM_CLASSES * DIM];
        assert!(engine.run(&[0u8; 10], &am, 1).is_err());
        let codes = vec![0u8; FRAMES_PER_PREDICTION * CHANNELS];
        assert!(engine.run(&codes, &[0i32; 5], 1).is_err());
        assert!(engine.run(&codes, &am, -1).is_err());
        assert_eq!(engine.kind(), EngineKind::SparseWindow);
    }

    #[test]
    fn stateless_across_runs() {
        // Repeated runs over the same inputs must agree (the encoder is
        // reset per job, so no window state leaks between jobs).
        let mut rng = Xoshiro256::new(0xA11CE);
        let codes_a = random_codes(&mut rng);
        let codes_b = random_codes(&mut rng);
        let am = AssociativeMemory::new(Hv::random(&mut rng, 0.3), Hv::random(&mut rng, 0.3));
        let mut engine =
            NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
        let first = engine.run(&codes_a, &am.to_i32s(), 130).unwrap();
        engine.run(&codes_b, &am.to_i32s(), 130).unwrap();
        let again = engine.run(&codes_a, &am.to_i32s(), 130).unwrap();
        assert_eq!(first.scores, again.scores);
        assert_eq!(first.query, again.query);
    }

    #[test]
    fn run_batch_matches_serial_runs() {
        let mut rng = Xoshiro256::new(0xBA7C);
        let am = AssociativeMemory::new(Hv::random(&mut rng, 0.3), Hv::random(&mut rng, 0.3));
        let plane = AmPlane::from_memory(&am);
        let thresholds = [40i32, 130, 200];
        let codes: Vec<u8> = (0..thresholds.len() * WINDOW_CODES)
            .map(|_| rng.next_below(LBP_CODES as u64) as u8)
            .collect();
        for kind in [EngineKind::SparseWindow, EngineKind::DenseWindow] {
            let cfg = if kind == EngineKind::SparseWindow {
                ClassifierConfig::optimized()
            } else {
                ClassifierConfig::default()
            };
            let mut engine = NativeWindowEngine::new(kind, cfg);
            let batch = engine.run_batch(&codes, &plane, &thresholds).unwrap();
            assert_eq!(batch.len(), thresholds.len());
            for (w, &t) in thresholds.iter().enumerate() {
                let serial = engine
                    .run(&codes[w * WINDOW_CODES..(w + 1) * WINDOW_CODES], plane.i32s(), t)
                    .unwrap();
                assert_eq!(batch[w].scores, serial.scores, "{kind:?} window {w}");
                assert_eq!(batch[w].query, serial.query, "{kind:?} window {w}");
            }
        }
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let am = AmPlane::from_memory(&AssociativeMemory::new(Hv::zero(), Hv::ones()));
        let mut engine =
            NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
        assert!(engine.run_batch(&[], &am, &[]).unwrap().is_empty());
        // Mismatched codes/thresholds shapes are rejected.
        assert!(engine.run_batch(&[0u8; WINDOW_CODES], &am, &[]).is_err());
        assert!(engine.run_batch(&[], &am, &[130]).is_err());
        // One bad threshold rejects the whole batch atomically.
        let codes = vec![0u8; 2 * WINDOW_CODES];
        assert!(engine.run_batch(&codes, &am, &[130, -1]).is_err());
    }

    #[test]
    fn am_plane_decode_reused_across_batches() {
        // Regression guard for the old per-call `plane_hv` rebuild: an
        // i32-sourced plane shared by many run_batch calls decodes once.
        let mut rng = Xoshiro256::new(0xDECD);
        let am = AssociativeMemory::new(Hv::random(&mut rng, 0.3), Hv::random(&mut rng, 0.3));
        let plane = AmPlane::from_i32s(&am.to_i32s()).unwrap();
        let codes: Vec<u8> = (0..WINDOW_CODES)
            .map(|_| rng.next_below(LBP_CODES as u64) as u8)
            .collect();
        let mut engine =
            NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
        for _ in 0..4 {
            engine.run_batch(&codes, &plane, &[130]).unwrap();
        }
        assert_eq!(plane.decode_count(), 1, "plane must be decoded exactly once");
    }
}
