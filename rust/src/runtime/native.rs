//! Native window engine: the bit-accurate golden model behind the same
//! `(codes, am, threshold) →` [`WindowOutput`] contract as the PJRT
//! engine, so the coordinator's serving path is fully exercisable in the
//! default (dependency-free) build — no artifacts, no `xla`.
//!
//! Semantics mirror the HLO models exactly (`cross_language.rs` pins the
//! PJRT engine against the same golden model):
//!
//! * **sparse**: CompIM bind → OR bundling → 256-frame temporal counters →
//!   thinning at the *per-job* threshold → AND-popcount scores against the
//!   AM plane (packed popcount — 64 word ops per class instead of 1024
//!   multiplies, §Perf L3-3);
//! * **dense**: XOR bind → majority bundling → temporal majority →
//!   `DIM - hamming` scores (normalised "bigger = more similar").

use crate::ensure;
use crate::hdc::classifier::{
    ClassifierConfig, DenseEncoder, Encoder, Frame, SparseEncoder, Variant,
};
use crate::hdc::hv::Hv;
use crate::params::{CHANNELS, DIM, FRAMES_PER_PREDICTION, NUM_CLASSES};

use super::{EngineKind, WindowOutput};

/// One native engine wrapping a streaming encoder of the requested kind.
///
/// Mutable because the encoder carries window state; the engine pool gives
/// each engine to a dedicated worker thread, exactly like the PJRT one.
pub struct NativeWindowEngine {
    kind: EngineKind,
    encoder: EncoderSlot,
}

enum EncoderSlot {
    Sparse(Box<SparseEncoder>),
    Dense(Box<DenseEncoder>),
}

impl NativeWindowEngine {
    pub fn new(kind: EngineKind, cfg: ClassifierConfig) -> NativeWindowEngine {
        let encoder = match kind {
            EngineKind::SparseWindow => {
                EncoderSlot::Sparse(Box::new(SparseEncoder::new(Variant::Optimized, cfg)))
            }
            EngineKind::DenseWindow => EncoderSlot::Dense(Box::new(DenseEncoder::new(cfg))),
        };
        NativeWindowEngine { kind, encoder }
    }

    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    /// Execute one window. Same contract as the PJRT engine's `run`:
    /// `codes` is one full frame-major window, `am` the
    /// `[NUM_CLASSES * DIM]` 0/1 plane, `threshold` the temporal thinning
    /// threshold (ignored by the dense model).
    pub fn run(&mut self, codes: &[u8], am: &[i32], threshold: i32) -> crate::Result<WindowOutput> {
        ensure!(
            codes.len() == FRAMES_PER_PREDICTION * CHANNELS,
            "codes length {} != {}",
            codes.len(),
            FRAMES_PER_PREDICTION * CHANNELS
        );
        ensure!(am.len() == NUM_CLASSES * DIM, "am length {}", am.len());

        match &mut self.encoder {
            EncoderSlot::Sparse(enc) => {
                // The dense model ignores `threshold` (PJRT contract), so
                // only the sparse path range-checks it.
                ensure!(
                    (0..=u16::MAX as i32).contains(&threshold),
                    "threshold {threshold} out of range"
                );
                enc.set_temporal_threshold(threshold as u16);
                let query = encode_window(enc.as_mut(), codes);
                let mut scores = [0i32; NUM_CLASSES];
                for (class, score) in scores.iter_mut().enumerate() {
                    let class_hv = plane_hv(am, class);
                    *score = query.overlap(&class_hv) as i32;
                }
                Ok(WindowOutput {
                    scores,
                    query: query.to_i32s(),
                })
            }
            EncoderSlot::Dense(enc) => {
                let query = encode_window(enc.as_mut(), codes);
                let mut scores = [0i32; NUM_CLASSES];
                for (class, score) in scores.iter_mut().enumerate() {
                    let class_hv = plane_hv(am, class);
                    *score = DIM as i32 - query.hamming(&class_hv) as i32;
                }
                Ok(WindowOutput {
                    scores,
                    query: query.to_i32s(),
                })
            }
        }
    }
}

/// Drive one full window through a streaming encoder.
fn encode_window(enc: &mut dyn Encoder, codes: &[u8]) -> Hv {
    enc.reset();
    let mut frame = [0u8; CHANNELS];
    let mut query = None;
    for chunk in codes.chunks_exact(CHANNELS) {
        frame.copy_from_slice(chunk);
        let f: Frame = frame;
        if let Some(q) = enc.push_frame(&f) {
            query = Some(q);
        }
    }
    // codes length was validated to exactly one window.
    query.expect("one full window emits exactly one query")
}

/// Rebuild one class HV from the flat i32 AM plane.
fn plane_hv(am: &[i32], class: usize) -> Hv {
    let plane = &am[class * DIM..(class + 1) * DIM];
    Hv::from_fn(|i| plane[i] != 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::am::AssociativeMemory;
    use crate::params::LBP_CODES;
    use crate::rng::Xoshiro256;

    fn random_codes(rng: &mut Xoshiro256) -> Vec<u8> {
        (0..FRAMES_PER_PREDICTION * CHANNELS)
            .map(|_| rng.next_below(LBP_CODES as u64) as u8)
            .collect()
    }

    #[test]
    fn sparse_engine_matches_inline_golden_model() {
        let mut rng = Xoshiro256::new(0xBEEF);
        let codes = random_codes(&mut rng);
        let am = AssociativeMemory::new(Hv::random(&mut rng, 0.3), Hv::random(&mut rng, 0.3));
        let threshold = 90u16;

        let cfg = ClassifierConfig {
            temporal_threshold: threshold,
            ..ClassifierConfig::optimized()
        };
        let mut enc = SparseEncoder::new(Variant::Optimized, cfg);
        let query = encode_window(&mut enc, &codes);
        let expect_scores = [
            query.overlap(&am.classes[0]) as i32,
            query.overlap(&am.classes[1]) as i32,
        ];

        let mut engine =
            NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
        let out = engine.run(&codes, &am.to_i32s(), threshold as i32).unwrap();
        assert_eq!(out.query, query.to_i32s());
        assert_eq!(out.scores, expect_scores);
    }

    #[test]
    fn per_job_threshold_is_honoured() {
        // The PJRT engine takes the threshold per call; the native engine
        // must too (a session's tuned threshold rides on the Job).
        let mut rng = Xoshiro256::new(0xCAFE);
        let codes = random_codes(&mut rng);
        let am = vec![0i32; NUM_CLASSES * DIM];
        let mut engine =
            NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
        let loose = engine.run(&codes, &am, 40).unwrap();
        let tight = engine.run(&codes, &am, 200).unwrap();
        let ones = |q: &[i32]| q.iter().filter(|&&b| b != 0).count();
        assert!(
            ones(&loose.query) > ones(&tight.query),
            "lower threshold must yield a denser query ({} vs {})",
            ones(&loose.query),
            ones(&tight.query)
        );
    }

    #[test]
    fn dense_engine_scores_are_normalised_hamming() {
        let mut rng = Xoshiro256::new(0xD0D0);
        let codes = random_codes(&mut rng);
        let am = AssociativeMemory::new(Hv::random_half(&mut rng), Hv::random_half(&mut rng));

        let mut enc = DenseEncoder::new(ClassifierConfig::default());
        let query = encode_window(&mut enc, &codes);
        let expect_scores = [
            DIM as i32 - query.hamming(&am.classes[0]) as i32,
            DIM as i32 - query.hamming(&am.classes[1]) as i32,
        ];

        let mut engine =
            NativeWindowEngine::new(EngineKind::DenseWindow, ClassifierConfig::default());
        let out = engine.run(&codes, &am.to_i32s(), 0).unwrap();
        assert_eq!(out.query, query.to_i32s());
        assert_eq!(out.scores, expect_scores);
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut engine =
            NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
        let am = vec![0i32; NUM_CLASSES * DIM];
        assert!(engine.run(&[0u8; 10], &am, 1).is_err());
        let codes = vec![0u8; FRAMES_PER_PREDICTION * CHANNELS];
        assert!(engine.run(&codes, &[0i32; 5], 1).is_err());
        assert!(engine.run(&codes, &am, -1).is_err());
        assert_eq!(engine.kind(), EngineKind::SparseWindow);
    }

    #[test]
    fn stateless_across_runs() {
        // Repeated runs over the same inputs must agree (the encoder is
        // reset per job, so no window state leaks between jobs).
        let mut rng = Xoshiro256::new(0xA11CE);
        let codes_a = random_codes(&mut rng);
        let codes_b = random_codes(&mut rng);
        let am = AssociativeMemory::new(Hv::random(&mut rng, 0.3), Hv::random(&mut rng, 0.3));
        let mut engine =
            NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
        let first = engine.run(&codes_a, &am.to_i32s(), 130).unwrap();
        engine.run(&codes_b, &am.to_i32s(), 130).unwrap();
        let again = engine.run(&codes_a, &am.to_i32s(), 130).unwrap();
        assert_eq!(first.scores, again.scores);
        assert_eq!(first.query, again.query);
    }
}
