//! PJRT execution path (cargo feature `pjrt`): load the AOT HLO-text
//! artifacts produced by `python/compile/aot.py` and execute them through
//! the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `compile` → `execute`), with input/output marshalling matching the
//! signatures in `artifacts/manifest.txt`.
//!
//! Python never runs here — `make artifacts` happened at build time. One
//! [`WindowEngine`] wraps one compiled model variant; engines are `Send`
//! but not `Sync` (PJRT buffers are single-threaded here), so the
//! coordinator gives each engine to a dedicated worker thread
//! ([`super::engine_pool`]).
//!
//! The workspace vendors an offline stub of `xla` (`rust/vendor/xla`)
//! that type-checks this module and fails at runtime with an actionable
//! message; swap in the real xla-rs to execute HLO (README §PJRT).

use std::path::{Path, PathBuf};

use crate::error::Context;
use crate::params::{CHANNELS, DIM, NUM_CLASSES};
use crate::{ensure, err};

use super::{EngineKind, Manifest, WindowOutput};

/// A compiled, ready-to-execute prediction-window model.
///
/// The item-memory tables are *inputs* of the HLO (large constants do not
/// survive the HLO-text interchange — the printer elides them); the
/// engine regenerates them from [`crate::hdc::im`] at load time (the
/// manifest digest guarantees bit-equality with the Python side) and
/// binds them on every call.
pub struct WindowEngine {
    exe: xla::PjRtLoadedExecutable,
    /// Pre-built table literals, in artifact parameter order (between
    /// `codes` and `am`).
    tables: Vec<xla::Literal>,
    pub kind: EngineKind,
    pub frames: usize,
    pub path: PathBuf,
}

/// Flattened sparse tables: (im_pos i32[CH,CODES,SEG], elec i32[CH,SEG]).
fn sparse_table_literals(seed: u64) -> crate::Result<Vec<xla::Literal>> {
    use crate::params::{LBP_CODES, SEGMENTS};
    let im = crate::hdc::im::ItemMemory::generate(seed);
    let mut impos = Vec::with_capacity(CHANNELS * LBP_CODES * SEGMENTS);
    for c in 0..CHANNELS {
        for k in 0..LBP_CODES {
            let pos = im.lookup(c, k as u8);
            impos.extend(pos.pos.iter().map(|&p| p as i32));
        }
    }
    let mut elec = Vec::with_capacity(CHANNELS * SEGMENTS);
    for c in 0..CHANNELS {
        elec.extend(im.electrode(c).pos.iter().map(|&p| p as i32));
    }
    let impos_lit = xla::Literal::vec1(&impos)
        .reshape(&[CHANNELS as i64, LBP_CODES as i64, SEGMENTS as i64])
        .map_err(|e| err!("reshape im_pos: {e}"))?;
    let elec_lit = xla::Literal::vec1(&elec)
        .reshape(&[CHANNELS as i64, SEGMENTS as i64])
        .map_err(|e| err!("reshape elec_pos: {e}"))?;
    Ok(vec![impos_lit, elec_lit])
}

/// Flattened dense tables: (im_bits, elec_bits, tie_s, tie_t).
fn dense_table_literals(seed: u64) -> crate::Result<Vec<xla::Literal>> {
    use crate::params::LBP_CODES;
    let im = crate::hdc::im::DenseItemMemory::generate(seed);
    let mut im_bits = Vec::with_capacity(LBP_CODES * DIM);
    for k in 0..LBP_CODES {
        im_bits.extend(im.lookup(k as u8).to_i32s());
    }
    let mut elec_bits = Vec::with_capacity(CHANNELS * DIM);
    for c in 0..CHANNELS {
        elec_bits.extend(im.electrode(c).to_i32s());
    }
    let tie_s = im.tiebreak(0).to_i32s();
    let tie_t = im.tiebreak(1).to_i32s();
    Ok(vec![
        xla::Literal::vec1(&im_bits)
            .reshape(&[LBP_CODES as i64, DIM as i64])
            .map_err(|e| err!("reshape im_bits: {e}"))?,
        xla::Literal::vec1(&elec_bits)
            .reshape(&[CHANNELS as i64, DIM as i64])
            .map_err(|e| err!("reshape elec_bits: {e}"))?,
        xla::Literal::vec1(&tie_s),
        xla::Literal::vec1(&tie_t),
    ])
}

impl WindowEngine {
    /// Load + compile one HLO-text artifact and build its table inputs.
    pub fn load(
        client: &xla::PjRtClient,
        path: &Path,
        kind: EngineKind,
        frames: usize,
        seed: u64,
    ) -> crate::Result<WindowEngine> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| err!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| err!("compile {}: {e}", path.display()))?;
        let tables = match kind {
            EngineKind::SparseWindow => sparse_table_literals(seed)?,
            EngineKind::DenseWindow => dense_table_literals(seed)?,
        };
        Ok(WindowEngine {
            exe,
            tables,
            kind,
            frames,
            path: path.to_path_buf(),
        })
    }

    /// Execute one window.
    ///
    /// `codes`: frame-major `[frames][CHANNELS]` LBP codes;
    /// `am`: `[NUM_CLASSES * DIM]` 0/1 plane; `threshold`: temporal
    /// thinning threshold (ignored by the dense model).
    pub fn run(&self, codes: &[u8], am: &[i32], threshold: i32) -> crate::Result<WindowOutput> {
        ensure!(
            codes.len() == self.frames * CHANNELS,
            "codes length {} != {}",
            codes.len(),
            self.frames * CHANNELS
        );
        ensure!(am.len() == NUM_CLASSES * DIM, "am length {}", am.len());

        let codes_i32: Vec<i32> = codes.iter().map(|&c| c as i32).collect();
        let codes_lit = xla::Literal::vec1(&codes_i32)
            .reshape(&[self.frames as i64, CHANNELS as i64])
            .map_err(|e| err!("reshape codes: {e}"))?;
        let am_lit = xla::Literal::vec1(am)
            .reshape(&[NUM_CLASSES as i64, DIM as i64])
            .map_err(|e| err!("reshape am: {e}"))?;

        // Parameter order (see aot.py): codes, <tables…>, am [, thr].
        let thr_lit = xla::Literal::vec1(&[threshold]);
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 + self.tables.len());
        args.push(&codes_lit);
        match self.kind {
            EngineKind::SparseWindow => {
                args.extend(self.tables.iter());
                args.push(&am_lit);
                args.push(&thr_lit);
            }
            EngineKind::DenseWindow => {
                args.extend(self.tables.iter());
                args.push(&am_lit);
            }
        }
        let result = self
            .exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| err!("execute {}: {e}", self.path.display()))?;

        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| err!("fetch result: {e}"))?;
        // aot.py lowers with return_tuple=True → (scores, query).
        let (scores_lit, query_lit) = out
            .to_tuple2()
            .map_err(|e| err!("untuple result: {e}"))?;
        let scores_vec = scores_lit
            .to_vec::<i32>()
            .map_err(|e| err!("scores: {e}"))?;
        let query = query_lit
            .to_vec::<i32>()
            .map_err(|e| err!("query: {e}"))?;
        ensure!(scores_vec.len() == NUM_CLASSES, "scores len {}", scores_vec.len());
        ensure!(query.len() == DIM, "query len {}", query.len());
        Ok(WindowOutput {
            scores: [scores_vec[0], scores_vec[1]],
            query,
        })
    }

    /// Execute a batch of `thresholds.len()` windows against one AM —
    /// the same contract as `NativeWindowEngine::run_batch`, so the
    /// native/pjrt A/B stays bit-exact at every batch size.
    ///
    /// The AOT artifacts currently take one window per call (a batched
    /// HLO entry point on the Python compile path is the remaining half —
    /// see ROADMAP), so this executes the windows serially; swapping in a
    /// batched artifact later cannot change the results, only the cost.
    pub fn run_batch(
        &self,
        codes: &[u8],
        am: &[i32],
        thresholds: &[i32],
    ) -> crate::Result<Vec<WindowOutput>> {
        let window = self.frames * CHANNELS;
        ensure!(
            codes.len() == thresholds.len() * window,
            "codes length {} != {} ({} windows of {})",
            codes.len(),
            thresholds.len() * window,
            thresholds.len(),
            window
        );
        codes
            .chunks_exact(window)
            .zip(thresholds)
            .map(|(chunk, &threshold)| self.run(chunk, am, threshold))
            .collect()
    }
}

/// The PJRT runtime: one CPU client + the artifact manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client and validate the artifacts in `dir`.
    pub fn new(dir: &Path) -> crate::Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        manifest.validate()?;
        let client = xla::PjRtClient::cpu().map_err(|e| err!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            dir: dir.to_path_buf(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn load_sparse(&self) -> crate::Result<WindowEngine> {
        WindowEngine::load(
            &self.client,
            &self.dir.join(&self.manifest.sparse_window),
            EngineKind::SparseWindow,
            self.manifest.frames,
            self.manifest.im_seed,
        )
    }

    pub fn load_dense(&self) -> crate::Result<WindowEngine> {
        WindowEngine::load(
            &self.client,
            &self.dir.join(&self.manifest.dense_window),
            EngineKind::DenseWindow,
            self.manifest.frames,
            self.manifest.im_seed,
        )
    }
}
