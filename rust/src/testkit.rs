//! Property-testing harness (proptest is unavailable offline —
//! DESIGN.md §2).
//!
//! Provides the essentials: a deterministic-but-varied case runner, value
//! generators over the crate's domain types, and failing-seed reporting so
//! a failure reproduces with `HDC_PROPTEST_SEED=<seed>`.
//!
//! ```no_run
//! use sparse_hdc_ieeg::testkit::{property, Gen};
//! property("bind is invertible", 256, |g: &mut Gen| {
//!     let a = g.sparse_hv();
//!     let b = g.sparse_hv();
//!     assert_eq!(a.bind(&b).unbind(&b), a);
//! });
//! ```

use crate::hdc::hv::Hv;
use crate::hdc::sparse::SparseHv;
use crate::params::{CHANNELS, LBP_CODES};
use crate::rng::Xoshiro256;

/// Per-case value generator.
pub struct Gen {
    rng: Xoshiro256,
    /// Seed of the current case (reported on failure).
    pub case_seed: u64,
}

impl Gen {
    pub fn new(case_seed: u64) -> Self {
        Gen {
            rng: Xoshiro256::new(case_seed),
            case_seed,
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.rng.next_below(n as u64) as usize
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.next_bool(p)
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.usize_below(hi - lo + 1)
    }

    pub fn sparse_hv(&mut self) -> SparseHv {
        SparseHv::random(&mut self.rng)
    }

    pub fn hv(&mut self, density: f64) -> Hv {
        Hv::random(&mut self.rng, density)
    }

    pub fn hv_half(&mut self) -> Hv {
        Hv::random_half(&mut self.rng)
    }

    pub fn lbp_code(&mut self) -> u8 {
        self.usize_below(LBP_CODES) as u8
    }

    pub fn frame(&mut self) -> [u8; CHANNELS] {
        let mut f = [0u8; CHANNELS];
        for c in f.iter_mut() {
            *c = self.lbp_code();
        }
        f
    }

    pub fn frames(&mut self, n: usize) -> Vec<[u8; CHANNELS]> {
        (0..n).map(|_| self.frame()).collect()
    }

    /// A vector of `n` values drawn by `f`.
    pub fn vec<T>(&mut self, n: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        (0..n).map(|_| f(self)).collect()
    }
}

/// Run `cases` property cases. Each case gets a [`Gen`] derived from the
/// master seed; panics are caught, annotated with the reproducing seed and
/// re-raised.
pub fn property(name: &str, cases: u64, f: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let master: u64 = std::env::var("HDC_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_MASTER_SEED);
    // When a specific seed is given, run exactly that case.
    let single = std::env::var("HDC_PROPTEST_SEED").is_ok();
    let n = if single { 1 } else { cases };
    for i in 0..n {
        let case_seed = if single {
            master
        } else {
            crate::rng::hash_chain(master, &[name.len() as u64, i])
        };
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(case_seed);
            f(&mut g);
        });
        if let Err(payload) = result {
            eprintln!(
                "\nproperty {name:?} failed on case {i}; reproduce with \
                 HDC_PROPTEST_SEED={case_seed}\n"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Master seed when `HDC_PROPTEST_SEED` is unset.
const DEFAULT_MASTER_SEED: u64 = 0x7E57_5EED_0BAD_F00D;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_in_domain() {
        let mut g = Gen::new(1);
        for _ in 0..100 {
            assert!(g.lbp_code() < LBP_CODES as u8);
            let r = g.range(3, 9);
            assert!((3..=9).contains(&r));
        }
        assert_eq!(g.frames(5).len(), 5);
    }

    #[test]
    fn property_runs_all_cases() {
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNT: AtomicU64 = AtomicU64::new(0);
        property("counting", 17, |_g| {
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 17);
    }

    #[test]
    fn cases_differ() {
        use std::sync::Mutex;
        let seen: Mutex<Vec<u64>> = Mutex::new(Vec::new());
        property("distinct-seeds", 8, |g| {
            seen.lock().unwrap().push(g.case_seed);
        });
        let v = seen.into_inner().unwrap();
        let mut dedup = v.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(v.len(), dedup.len(), "case seeds must be distinct");
    }
}
