//! Property-testing harness (proptest is unavailable offline —
//! DESIGN.md §2).
//!
//! Provides the essentials: a deterministic-but-varied case runner, value
//! generators over the crate's domain types, and failing-seed reporting so
//! a failure reproduces with `HDC_PROPTEST_SEED=<seed>`.
//!
//! ```no_run
//! use sparse_hdc_ieeg::testkit::{property, Gen};
//! property("bind is invertible", 256, |g: &mut Gen| {
//!     let a = g.sparse_hv();
//!     let b = g.sparse_hv();
//!     assert_eq!(a.bind(&b).unbind(&b), a);
//! });
//! ```

use crate::hdc::hv::Hv;
use crate::hdc::model::CounterPlanes;
use crate::hdc::sparse::SparseHv;
use crate::params::{CHANNELS, DIM, LBP_CODES};
use crate::rng::Xoshiro256;

/// Per-case value generator.
pub struct Gen {
    rng: Xoshiro256,
    /// Seed of the current case (reported on failure).
    pub case_seed: u64,
}

impl Gen {
    pub fn new(case_seed: u64) -> Self {
        Gen {
            rng: Xoshiro256::new(case_seed),
            case_seed,
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.rng.next_below(n as u64) as usize
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.next_bool(p)
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.usize_below(hi - lo + 1)
    }

    pub fn sparse_hv(&mut self) -> SparseHv {
        SparseHv::random(&mut self.rng)
    }

    pub fn hv(&mut self, density: f64) -> Hv {
        Hv::random(&mut self.rng, density)
    }

    pub fn hv_half(&mut self) -> Hv {
        Hv::random_half(&mut self.rng)
    }

    pub fn lbp_code(&mut self) -> u8 {
        self.usize_below(LBP_CODES) as u8
    }

    pub fn frame(&mut self) -> [u8; CHANNELS] {
        let mut f = [0u8; CHANNELS];
        for c in f.iter_mut() {
            *c = self.lbp_code();
        }
        f
    }

    pub fn frames(&mut self, n: usize) -> Vec<[u8; CHANNELS]> {
        (0..n).map(|_| self.frame()).collect()
    }

    /// A vector of `n` values drawn by `f`.
    pub fn vec<T>(&mut self, n: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        (0..n).map(|_| f(self)).collect()
    }

    /// Randomized model counter planes ([`random_counter_planes`]).
    pub fn counter_planes(&mut self) -> CounterPlanes {
        random_counter_planes(&mut self.rng)
    }
}

/// Randomized [`CounterPlanes`] — the one test-side builder the
/// bundle-format, persistence and scheduler suites share, so a
/// counter-plane schema change has a single home.
pub fn random_counter_planes(rng: &mut Xoshiro256) -> CounterPlanes {
    let mut counts = [Box::new([0u32; DIM]), Box::new([0u32; DIM])];
    for plane in counts.iter_mut() {
        for c in plane.iter_mut() {
            *c = (rng.next_u64() & 0x1FF) as u32;
        }
    }
    CounterPlanes {
        counts,
        windows: [rng.next_below(500), rng.next_below(500)],
    }
}

/// A small two-record synthetic patient (14 s per record: 8 s lead-in,
/// 4 s seizure, 2 s tail) plus a one-shot-trained v1
/// [`crate::hdc::model::ModelBundle`] re-keyed to the patient — the
/// shared fixture of the model-lifecycle suites (record 0 trains,
/// record 1 streams). One home so the suites can't drift on synth
/// shape or bundle seeding.
pub fn tiny_trained_patient(
    pid: u32,
) -> (
    crate::data::synth::SynthPatient,
    crate::hdc::model::ModelBundle,
) {
    use crate::data::synth::{SynthConfig, SynthPatient};
    use crate::hdc::classifier::{ClassifierConfig, SparseEncoder, Variant};

    let synth = SynthConfig {
        records_per_patient: 2,
        pre_s: 8.0,
        ictal_s: 4.0,
        post_s: 2.0,
        ..Default::default()
    };
    let patient = SynthPatient::generate(&synth, pid);
    let cfg = ClassifierConfig::optimized();
    let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
    let mut bundle = crate::pipeline::train_on_record(&mut enc, patient.train_record(), &cfg);
    bundle.provenance.patient_id = pid;
    (patient, bundle)
}

/// A random wire frame of every kind — the shared generator of the
/// codec round-trip / corruption property suites. Samples runs are kept
/// small (≤ 4 multichannel samples) so byte-level corruption sweeps stay
/// fast; the codec's size limits have their own directed tests.
pub fn wire_frame(g: &mut Gen) -> crate::transport::frame::Frame {
    use crate::transport::frame::{Frame, PatientStatus};
    match g.usize_below(10) {
        0 => Frame::Subscribe {
            patient: g.u64() as u32,
        },
        1 => {
            let n = g.range(0, 4);
            Frame::Samples {
                seq: g.u64(),
                samples: g.vec(n * crate::params::CHANNELS, |g| {
                    // Random mantissa + sign with a fixed finite
                    // exponent: the codec moves f32 bits, not values,
                    // but the round-trip asserts equality, so NaN (the
                    // one bit pattern where x != x) must not appear.
                    f32::from_bits(((g.u64() as u32) & !0x7F80_0000) | 0x3F80_0000)
                }),
            }
        }
        2 => Frame::Prediction {
            window: g.u64(),
            is_ictal: g.bool(0.5),
            margin: g.u64() as i64,
            model_version: g.u64(),
        },
        3 => Frame::Heartbeat { seq: g.u64() },
        4 => Frame::ShardHello {
            shard: g.u64() as u32,
            epoch: g.u64(),
        },
        5 => Frame::Lease {
            patient: g.u64() as u32,
            shard: g.u64() as u32,
            epoch: g.u64(),
        },
        6 => Frame::Route {
            patient: g.u64() as u32,
            shard: g.u64() as u32,
            addr: match g.usize_below(3) {
                0 => String::new(),
                1 => "127.0.0.1:7001".to_string(),
                _ => "[::1]:65535".to_string(),
            },
        },
        7 => Frame::Status,
        8 => {
            // Entries ascend strictly by patient id and keep
            // fa_hits ≤ fa_seen — the invariants the decoder enforces.
            let n = g.range(0, 3);
            let mut patient = g.u64() as u32 & 0xFFFF;
            let patients = g.vec(n, |g| {
                patient += 1 + (g.usize_below(9) as u32);
                let fa_seen = g.usize_below(100) as u32;
                PatientStatus {
                    patient,
                    fa_hits: g.usize_below(fa_seen as usize + 1) as u32,
                    fa_seen,
                    retrains: g.usize_below(4) as u32,
                    triggers: g.usize_below(4) as u32,
                    feedback_depth: g.usize_below(64) as u32,
                }
            });
            Frame::StatusReport {
                cache_hits: g.u64(),
                cache_misses: g.u64(),
                cache_evictions: g.u64(),
                cache_redecodes: g.u64(),
                patients,
            }
        }
        _ => Frame::Shutdown {
            reason: match g.usize_below(3) {
                0 => String::new(),
                1 => "end of stream".to_string(),
                _ => "reason with unicode — π≈3.14159".to_string(),
            },
        },
    }
}

/// A [`std::io::Read`] wrapper that returns at most `max_step` bytes per
/// call (driven by its own tiny RNG) — exercises partial-read
/// reassembly in stream decoders the way a congested socket would.
pub struct TrickleReader<R> {
    inner: R,
    rng: Xoshiro256,
    max_step: usize,
}

impl<R: std::io::Read> TrickleReader<R> {
    pub fn new(inner: R, seed: u64, max_step: usize) -> Self {
        TrickleReader {
            inner,
            rng: Xoshiro256::new(seed),
            max_step: max_step.max(1),
        }
    }
}

impl<R: std::io::Read> std::io::Read for TrickleReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let step = 1 + self.rng.next_below(self.max_step as u64) as usize;
        let n = step.min(buf.len());
        self.inner.read(&mut buf[..n])
    }
}

/// A unique scratch directory under the system temp dir (removed first
/// if a previous run left one). Unique per (tag, process, thread), so
/// parallel test binaries and threads never collide. Not auto-deleted —
/// tests remove it on success so failures leave evidence behind.
pub fn scratch_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "hdc_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// A synthetic per-window outcome stream (`true` = the window was a
/// false alarm) with one planted burst of consecutive false alarms:
/// clean everywhere except `[burst_start, burst_start + burst_len)`.
/// The retrain-scheduler tests feed this to
/// [`crate::coordinator::scheduler::PatientWatch`] and pin the exact
/// window index the policy fires at; other tests can reuse it wherever
/// a deterministic false-alarm pattern is needed.
pub fn planted_false_alarm_stream(total: usize, burst_start: usize, burst_len: usize) -> Vec<bool> {
    assert!(
        burst_start + burst_len <= total,
        "burst [{burst_start}, {}) does not fit in {total} windows",
        burst_start + burst_len
    );
    (0..total)
        .map(|i| i >= burst_start && i < burst_start + burst_len)
        .collect()
}

/// Deterministic, seed-keyed fault injection for sample streams.
///
/// Every corruption an injector applies is a pure function of
/// `(seed, injector position, stream contents)` — no ambient randomness,
/// no time — so two runs with the same seed produce bit-identical
/// hostile streams (and therefore bit-identical prediction streams, the
/// reproducibility contract `tests/hostile_streams.rs` and the CI chaos
/// job pin). Injectors compose in declaration order; each derives its
/// own RNG stream from the master seed and its position, so adding an
/// injector never perturbs the ones before it.
pub mod hostile {
    use crate::params::CHANNELS;
    use crate::rng::{hash_chain, Xoshiro256};
    use crate::{bail, ensure};

    /// One composable corruption.
    #[derive(Clone, Debug, PartialEq)]
    pub enum Injector {
        /// Electrode dropout: each channel independently suffers one
        /// dead span of `span_frames` samples with probability `rate`,
        /// either zeroed (lead lifted) or stuck at the last good value
        /// (frozen ADC) when `stuck` is set.
        Dropout {
            rate: f64,
            span_frames: usize,
            stuck: bool,
        },
        /// Amplitude drift: a linear gain ramp over all channels, from
        /// 1.0 at `start_frame` to `gain` at the end of the stream —
        /// the inter-session signal change of Pale et al., compressed
        /// into one record so it can force `RetrainPolicy` triggers.
        Drift { start_frame: usize, gain: f32 },
        /// Label noise in the feedback path: each window's ground-truth
        /// label flips with probability `p` before it reaches the
        /// retrain loop (sample bytes are untouched).
        LabelNoise { p: f64 },
        /// Sample jitter at the chunk boundary: with probability `p`,
        /// the multichannel frames on either side of each
        /// `chunk_frames` boundary swap places (a reordered wire chunk).
        Jitter { chunk_frames: usize, p: f64 },
    }

    impl Injector {
        /// Parse one `name[=param]` spec item (the `--hostile` CLI
        /// vocabulary) into an injector with calibrated defaults.
        fn parse(name: &str) -> crate::Result<Injector> {
            Ok(match name.trim() {
                "dropout" => Injector::Dropout {
                    rate: 0.25,
                    span_frames: 64,
                    stuck: false,
                },
                "stuck" => Injector::Dropout {
                    rate: 0.25,
                    span_frames: 64,
                    stuck: true,
                },
                "drift" => Injector::Drift {
                    start_frame: 0,
                    gain: 6.0,
                },
                "label-noise" => Injector::LabelNoise { p: 0.05 },
                "jitter" => Injector::Jitter {
                    chunk_frames: 256,
                    p: 0.5,
                },
                other => bail!(
                    "unknown hostile injector {other:?} \
                     (known: dropout, stuck, drift, label-noise, jitter)"
                ),
            })
        }

        fn corrupt(&self, seed: u64, samples: &mut [f32]) {
            let frames = samples.len() / CHANNELS;
            match self {
                Injector::Dropout {
                    rate,
                    span_frames,
                    stuck,
                } => {
                    let mut rng = Xoshiro256::new(seed);
                    for c in 0..CHANNELS {
                        // Draw both decisions unconditionally so each
                        // channel's corruption is independent of the
                        // spans the channels before it drew.
                        let hit = rng.next_bool(*rate);
                        let len = (*span_frames).min(frames);
                        if frames == 0 || len == 0 {
                            continue;
                        }
                        let start = rng.next_below((frames - len + 1) as u64) as usize;
                        if !hit {
                            continue;
                        }
                        let held = if *stuck && start > 0 {
                            samples[(start - 1) * CHANNELS + c]
                        } else {
                            0.0
                        };
                        for t in start..start + len {
                            samples[t * CHANNELS + c] = held;
                        }
                    }
                }
                Injector::Drift { start_frame, gain } => {
                    if frames <= *start_frame {
                        return;
                    }
                    let span = (frames - start_frame) as f32;
                    for t in *start_frame..frames {
                        let g = 1.0 + (gain - 1.0) * ((t - start_frame) as f32 + 1.0) / span;
                        for s in &mut samples[t * CHANNELS..(t + 1) * CHANNELS] {
                            *s *= g;
                        }
                    }
                }
                Injector::Jitter { chunk_frames, p } => {
                    if *chunk_frames == 0 {
                        return;
                    }
                    let mut rng = Xoshiro256::new(seed);
                    let mut k = *chunk_frames;
                    while k < frames {
                        if rng.next_bool(*p) {
                            for c in 0..CHANNELS {
                                samples.swap((k - 1) * CHANNELS + c, k * CHANNELS + c);
                            }
                        }
                        k += chunk_frames;
                    }
                }
                Injector::LabelNoise { .. } => {}
            }
        }

        fn corrupt_label(&self, seed: u64, window: u64, label: bool) -> bool {
            match self {
                Injector::LabelNoise { p } => {
                    // Keyed per window, not drawn from a running stream:
                    // the flip decision for window w is identical no
                    // matter how many windows were observed before it.
                    let mut rng = Xoshiro256::new(hash_chain(seed, &[window]));
                    if rng.next_bool(*p) {
                        !label
                    } else {
                        label
                    }
                }
                _ => label,
            }
        }
    }

    /// A seed-keyed stack of injectors wrapping one sample stream.
    #[derive(Clone, Debug, PartialEq)]
    pub struct HostileStream {
        pub seed: u64,
        pub injectors: Vec<Injector>,
    }

    impl HostileStream {
        pub fn new(seed: u64) -> Self {
            HostileStream {
                seed,
                injectors: Vec::new(),
            }
        }

        /// Append an injector (applied after the ones already present).
        pub fn with(mut self, injector: Injector) -> Self {
            self.injectors.push(injector);
            self
        }

        /// Parse a comma-separated `--hostile` spec (`"dropout,drift"`).
        pub fn parse(spec: &str, seed: u64) -> crate::Result<Self> {
            let mut hostile = HostileStream::new(seed);
            for name in spec.split(',').filter(|s| !s.trim().is_empty()) {
                hostile.injectors.push(Injector::parse(name)?);
            }
            ensure!(
                !hostile.injectors.is_empty(),
                "hostile spec {spec:?} names no injectors"
            );
            Ok(hostile)
        }

        /// The per-injector RNG seed: master seed chained with the
        /// injector's position.
        fn injector_seed(&self, index: usize) -> u64 {
            hash_chain(self.seed, &[index as u64])
        }

        /// Apply every sample-path injector, in order, to a whole
        /// time-major stream. Idempotent inputs are not assumed — call
        /// once per stream.
        pub fn corrupt(&self, samples: &mut [f32]) {
            debug_assert_eq!(samples.len() % CHANNELS, 0);
            for (i, inj) in self.injectors.iter().enumerate() {
                inj.corrupt(self.injector_seed(i), samples);
            }
        }

        /// Pass a window's ground-truth label through the feedback-path
        /// injectors (only `LabelNoise` touches it).
        pub fn corrupt_label(&self, window: u64, label: bool) -> bool {
            let mut label = label;
            for (i, inj) in self.injectors.iter().enumerate() {
                label = inj.corrupt_label(self.injector_seed(i), window, label);
            }
            label
        }

        /// True when no injector is configured (the stream is clean).
        pub fn is_empty(&self) -> bool {
            self.injectors.is_empty()
        }
    }

    /// Derive the per-session hostile seed loadgen uses: every session
    /// index gets its own reproducible corruption stream from one
    /// `--seed`.
    pub fn session_seed(master: u64, session: u64) -> u64 {
        hash_chain(master, &[0x5E55_1011, session])
    }
}

/// Run `cases` property cases. Each case gets a [`Gen`] derived from the
/// master seed; panics are caught, annotated with the reproducing seed and
/// re-raised.
pub fn property(name: &str, cases: u64, f: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let master: u64 = std::env::var("HDC_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_MASTER_SEED);
    // When a specific seed is given, run exactly that case.
    let single = std::env::var("HDC_PROPTEST_SEED").is_ok();
    let n = if single { 1 } else { cases };
    for i in 0..n {
        let case_seed = if single {
            master
        } else {
            crate::rng::hash_chain(master, &[name.len() as u64, i])
        };
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(case_seed);
            f(&mut g);
        });
        if let Err(payload) = result {
            eprintln!(
                "\nproperty {name:?} failed on case {i}; reproduce with \
                 HDC_PROPTEST_SEED={case_seed}\n"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Master seed when `HDC_PROPTEST_SEED` is unset.
const DEFAULT_MASTER_SEED: u64 = 0x7E57_5EED_0BAD_F00D;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_in_domain() {
        let mut g = Gen::new(1);
        for _ in 0..100 {
            assert!(g.lbp_code() < LBP_CODES as u8);
            let r = g.range(3, 9);
            assert!((3..=9).contains(&r));
        }
        assert_eq!(g.frames(5).len(), 5);
    }

    #[test]
    fn planted_stream_shape() {
        let s = planted_false_alarm_stream(10, 4, 3);
        assert_eq!(s.len(), 10);
        assert_eq!(s.iter().filter(|&&b| b).count(), 3);
        assert!(!s[3] && s[4] && s[6] && !s[7]);
        // A zero-length burst is a clean stream.
        assert!(planted_false_alarm_stream(5, 2, 0).iter().all(|&b| !b));
    }

    #[test]
    #[should_panic(expected = "does not fit")]
    fn planted_stream_rejects_overflowing_burst() {
        planted_false_alarm_stream(8, 6, 4);
    }

    #[test]
    fn hostile_injectors_are_seed_deterministic() {
        use hostile::{HostileStream, Injector};
        let mut g = Gen::new(42);
        let clean: Vec<f32> = g.vec(512 * CHANNELS, |g| g.f64() as f32 - 0.5);
        let h = HostileStream::parse("dropout,drift,jitter", 7).unwrap();
        let mut a = clean.clone();
        let mut b = clean.clone();
        h.corrupt(&mut a);
        h.corrupt(&mut b);
        assert_eq!(a.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
                   b.iter().map(|s| s.to_bits()).collect::<Vec<_>>());
        assert_ne!(a.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
                   clean.iter().map(|s| s.to_bits()).collect::<Vec<_>>());
        // A different seed corrupts differently.
        let mut c = clean.clone();
        HostileStream::parse("dropout,drift,jitter", 8).unwrap().corrupt(&mut c);
        assert_ne!(a.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
                   c.iter().map(|s| s.to_bits()).collect::<Vec<_>>());

        // Appending an injector never perturbs the ones before it: the
        // dropout spans of "dropout" and "dropout,drift" coincide.
        let solo = HostileStream::new(7).with(Injector::Dropout {
            rate: 1.0,
            span_frames: 16,
            stuck: false,
        });
        let stacked = solo.clone().with(Injector::Drift {
            start_frame: 1 << 30, // past the end: drift is a no-op
            gain: 3.0,
        });
        let mut x = clean.clone();
        let mut y = clean.clone();
        solo.corrupt(&mut x);
        stacked.corrupt(&mut y);
        assert_eq!(x.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
                   y.iter().map(|s| s.to_bits()).collect::<Vec<_>>());
    }

    #[test]
    fn hostile_label_noise_is_per_window_and_sample_silent() {
        use hostile::HostileStream;
        let h = HostileStream::parse("label-noise", 11).unwrap();
        // Samples pass through untouched.
        let mut g = Gen::new(1);
        let clean: Vec<f32> = g.vec(64 * CHANNELS, |g| g.f64() as f32);
        let mut s = clean.clone();
        h.corrupt(&mut s);
        assert_eq!(s.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                   clean.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
        // The flip decision for a window is a pure function of
        // (seed, window), independent of observation order.
        let forward: Vec<bool> = (0..200).map(|w| h.corrupt_label(w, false)).collect();
        let backward: Vec<bool> = (0..200).rev().map(|w| h.corrupt_label(w, false)).collect();
        assert_eq!(forward, backward.into_iter().rev().collect::<Vec<_>>());
        assert!(forward.iter().any(|&b| b), "p=0.05 over 200 windows should flip some");
        assert!(forward.iter().filter(|&&b| b).count() < 40, "flip rate far above p");
    }

    #[test]
    fn hostile_parse_rejects_unknown_and_empty() {
        assert!(hostile::HostileStream::parse("dropout,warp", 1).is_err());
        assert!(hostile::HostileStream::parse(" , ", 1).is_err());
        assert!(hostile::HostileStream::parse("stuck", 1).is_ok());
    }

    #[test]
    fn property_runs_all_cases() {
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNT: AtomicU64 = AtomicU64::new(0);
        property("counting", 17, |_g| {
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 17);
    }

    #[test]
    fn cases_differ() {
        use std::sync::Mutex;
        let seen: Mutex<Vec<u64>> = Mutex::new(Vec::new());
        property("distinct-seeds", 8, |g| {
            seen.lock().unwrap().push(g.case_seed);
        });
        let v = seen.into_inner().unwrap();
        let mut dedup = v.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(v.len(), dedup.len(), "case seeds must be distinct");
    }
}
