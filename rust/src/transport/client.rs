//! Streaming client: replay one record over a wire connection and
//! collect the server's predictions.
//!
//! One call = one session: `Subscribe`, chunked `Samples` frames in
//! sequence order, a closing `Shutdown`, then the server's final
//! `Shutdown` after the last prediction. A reader thread drains
//! predictions concurrently with the sample writes — without it, a
//! client pushing a long record while its predictions queue up would
//! look exactly like the slow consumer the server sheds.
//!
//! Latency accounting: the writer records an `Instant` each time the
//! samples it has sent complete one more prediction window; the reader
//! pairs predictions (which arrive in window order — the wire layer's
//! ordering guarantee) with those marks, so each prediction's latency is
//! "window fully on the wire → prediction frame read back".

use std::sync::mpsc::{channel, Receiver};
use std::time::{Duration, Instant};

use crate::ensure;
use crate::params::{CHANNELS, FRAMES_PER_PREDICTION};
use crate::transport::frame::{
    close, write_frame, Frame, FrameReader, ReadOutcome, MAX_SAMPLES_PER_FRAME,
};
use crate::transport::{Duplex, WireRead, WireWrite};

/// Client-side streaming knobs.
#[derive(Clone, Debug)]
pub struct StreamClientConfig {
    /// Multichannel samples per `Samples` frame (clamped to the frame
    /// cap). The server windows identically at any chunking — the LBP
    /// front-end is per-sample — so this only shapes wire traffic.
    pub chunk_samples: usize,
    /// Reader poll tick.
    pub read_timeout: Duration,
    /// Give up if the server goes silent (no frame of any kind, not even
    /// a heartbeat) for this long.
    pub silence_deadline: Duration,
}

impl Default for StreamClientConfig {
    fn default() -> Self {
        StreamClientConfig {
            chunk_samples: 256,
            read_timeout: Duration::from_millis(25),
            silence_deadline: Duration::from_secs(30),
        }
    }
}

/// One `Prediction` frame, as received.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WirePrediction {
    pub window: u64,
    pub is_ictal: bool,
    pub margin: i64,
    pub model_version: u64,
}

/// Everything one streamed session produced.
#[derive(Debug)]
pub struct StreamOutcome {
    pub predictions: Vec<WirePrediction>,
    /// Reason carried by the server's closing `Shutdown`; `None` when
    /// the connection ended with EOF instead (e.g. the server shed us).
    pub shutdown_reason: Option<String>,
    pub heartbeats: u64,
    /// Window-complete-on-wire → prediction-read latencies, one per
    /// received prediction, in prediction order.
    pub latencies: Vec<Duration>,
    /// A sample write failed mid-stream (server hung up on us); the
    /// predictions received up to that point are still returned.
    pub send_error: Option<String>,
    /// Windows fully written to the wire (the denominator for drops).
    pub windows_sent: u64,
    /// Placement announced by a fleet dispatcher (`Route` frame): the
    /// shard slot and data-plane address this session was proxied to.
    /// `None` when talking to a shard or standalone server directly.
    pub routed: Option<(u32, String)>,
}

impl StreamOutcome {
    /// Windows the server never answered (shed/dropped).
    pub fn dropped(&self) -> u64 {
        self.windows_sent.saturating_sub(self.predictions.len() as u64)
    }
}

/// Stream `samples` (time-major, whole multichannel frames) as
/// `patient`'s session over `conn`; returns once the server closes the
/// stream (or goes silent past the deadline).
pub fn stream_record(
    conn: Duplex,
    patient: u32,
    samples: &[f32],
    cfg: &StreamClientConfig,
) -> crate::Result<StreamOutcome> {
    ensure!(
        samples.len() % CHANNELS == 0,
        "record of {} f32s is not a whole number of {CHANNELS}-channel samples",
        samples.len()
    );
    let (mut reader, mut writer, _peer) = conn.split();
    reader.get_mut().set_read_timeout(Some(cfg.read_timeout))?;
    let (mark_tx, mark_rx) = channel::<Instant>();
    let silence = cfg.silence_deadline;
    let reader_handle = std::thread::Builder::new()
        .name("wire-client-read".into())
        .spawn(move || read_predictions(reader, mark_rx, silence))?;

    let chunk = cfg.chunk_samples.clamp(1, MAX_SAMPLES_PER_FRAME);
    let mut send_error = None;
    let mut windows_sent = 0u64;
    let mut sent_samples = 0usize; // multichannel samples on the wire
    let result = (|| -> crate::Result<()> {
        write_frame(&mut writer, &Frame::Subscribe { patient })?;
        for (seq, run) in samples.chunks(chunk * CHANNELS).enumerate() {
            write_frame(
                &mut writer,
                &Frame::Samples {
                    seq: seq as u64,
                    samples: run.to_vec(),
                },
            )?;
            let prev_windows = sent_samples / FRAMES_PER_PREDICTION;
            sent_samples += run.len() / CHANNELS;
            let now_windows = sent_samples / FRAMES_PER_PREDICTION;
            for _ in prev_windows..now_windows {
                windows_sent += 1;
                let _ = mark_tx.send(Instant::now());
            }
        }
        write_frame(
            &mut writer,
            &Frame::Shutdown {
                reason: close::END_OF_STREAM.into(),
            },
        )?;
        Ok(())
    })();
    if let Err(e) = result {
        // Server hung up mid-write (shed / stale / protocol error): the
        // reader still drains whatever was delivered before the close.
        send_error = Some(format!("{e:#}"));
    }
    drop(mark_tx);

    let mut outcome = reader_handle
        .join()
        .map_err(|_| crate::err!("wire client reader thread panicked"))??;
    outcome.send_error = send_error;
    outcome.windows_sent = windows_sent;
    Ok(outcome)
}

/// One `StatusReport` frame, as received: the server's plane-cache
/// counters plus a per-patient serving/retraining snapshot.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StatusSnapshot {
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub cache_redecodes: u64,
    pub patients: Vec<crate::transport::frame::PatientStatus>,
}

/// Send a `Status` query over `conn` and block for the `StatusReport`.
///
/// Heartbeats are tolerated while waiting (a status connection is just
/// another wire connection and gets keepalives like any other); any
/// other frame, a server `Shutdown`, or silence past the deadline is an
/// error — telemetry is strictly one request, one reply.
pub fn query_status(conn: Duplex, cfg: &StreamClientConfig) -> crate::Result<StatusSnapshot> {
    let (mut reader, mut writer, _peer) = conn.split();
    reader.get_mut().set_read_timeout(Some(cfg.read_timeout))?;
    write_frame(&mut writer, &Frame::Status)?;
    let mut last_frame = Instant::now();
    loop {
        match reader.read()? {
            ReadOutcome::Idle => {
                ensure!(
                    last_frame.elapsed() < cfg.silence_deadline,
                    "server went silent for {:?} awaiting a status report",
                    cfg.silence_deadline
                );
            }
            ReadOutcome::Eof => crate::bail!("server closed the connection before replying to Status"),
            ReadOutcome::Frame(frame) => {
                last_frame = Instant::now();
                match frame {
                    Frame::StatusReport {
                        cache_hits,
                        cache_misses,
                        cache_evictions,
                        cache_redecodes,
                        patients,
                    } => {
                        return Ok(StatusSnapshot {
                            cache_hits,
                            cache_misses,
                            cache_evictions,
                            cache_redecodes,
                            patients,
                        })
                    }
                    Frame::Heartbeat { .. } => {}
                    Frame::Shutdown { reason } => {
                        crate::bail!("server closed the status connection: {reason}")
                    }
                    other => crate::bail!(
                        "server answered Status with an unexpected frame: {}",
                        other.kind_name()
                    ),
                }
            }
        }
    }
}

fn read_predictions(
    mut reader: FrameReader<Box<dyn WireRead>>,
    marks: Receiver<Instant>,
    silence_deadline: Duration,
) -> crate::Result<StreamOutcome> {
    let mut outcome = StreamOutcome {
        predictions: Vec::new(),
        shutdown_reason: None,
        heartbeats: 0,
        latencies: Vec::new(),
        send_error: None,
        windows_sent: 0,
        routed: None,
    };
    let mut last_frame = Instant::now();
    loop {
        match reader.read()? {
            ReadOutcome::Idle => {
                ensure!(
                    last_frame.elapsed() < silence_deadline,
                    "server went silent for {silence_deadline:?} \
                     ({} predictions received)",
                    outcome.predictions.len()
                );
            }
            ReadOutcome::Eof => return Ok(outcome),
            ReadOutcome::Frame(frame) => {
                last_frame = Instant::now();
                match frame {
                    Frame::Prediction {
                        window,
                        is_ictal,
                        margin,
                        model_version,
                    } => {
                        // Predictions arrive in window order, and a
                        // window's mark is sent before the server can
                        // have seen its samples — so the matching mark
                        // is always already queued.
                        if let Ok(mark) = marks.try_recv() {
                            outcome.latencies.push(mark.elapsed());
                        }
                        outcome.predictions.push(WirePrediction {
                            window,
                            is_ictal,
                            margin,
                            model_version,
                        });
                    }
                    Frame::Heartbeat { .. } => outcome.heartbeats += 1,
                    Frame::Shutdown { reason } => {
                        outcome.shutdown_reason = Some(reason);
                        return Ok(outcome);
                    }
                    Frame::Route { shard, addr, .. } => {
                        outcome.routed = Some((shard, addr));
                    }
                    // Status telemetry is strictly request/reply — a
                    // report the client never asked for is a protocol
                    // violation, same as any other out-of-role frame.
                    Frame::Subscribe { .. }
                    | Frame::Samples { .. }
                    | Frame::ShardHello { .. }
                    | Frame::Lease { .. }
                    | Frame::Status
                    | Frame::StatusReport { .. } => {
                        crate::bail!("server sent an unexpected frame: {}", frame.kind_name())
                    }
                }
            }
        }
    }
}
