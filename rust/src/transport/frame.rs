//! Versioned binary wire-frame codec for the streaming service.
//!
//! Same discipline as the `ModelBundle` container
//! ([`crate::hdc::model`]): magic + format version up front, explicit
//! little-endian integers, every length validated *before* it sizes an
//! allocation, and a decoder that is total — corrupt bytes produce an
//! `Err`, never a panic, never an unbounded `Vec`. One frame on the
//! wire:
//!
//! ```text
//! "HDCW" (4) | version u8 | kind u8 | payload_len u32 LE | payload
//! ```
//!
//! | kind | frame        | payload                                           |
//! |------|--------------|---------------------------------------------------|
//! | 1    | `Subscribe`  | `patient u32`                                     |
//! | 2    | `Samples`    | `seq u64, n u32, n*CHANNELS f32 bits` (time-major)|
//! | 3    | `Prediction` | `window u64, model_version u64, margin i64, label u8` |
//! | 4    | `Heartbeat`  | `seq u64`                                         |
//! | 5    | `Shutdown`   | `len u32, len bytes UTF-8 reason`                 |
//! | 6    | `ShardHello` | `shard u32, epoch u64`                            |
//! | 7    | `Lease`      | `patient u32, shard u32, epoch u64`               |
//! | 8    | `Route`      | `patient u32, shard u32, len u32, len bytes addr` |
//! | 9    | `Status`     | (empty)                                           |
//! | 10   | `StatusReport` | `4×u64 plane-cache counters, n u32, n×(patient u32, fa_hits u32, fa_seen u32, retrains u32, triggers u32, feedback_depth u32)` |
//!
//! Streams are reassembled by [`FrameDecoder`], which accepts arbitrary
//! byte chunks (TCP segments, pipe writes) and yields whole frames —
//! partial reads never corrupt framing, they just return `Ok(None)`
//! until the rest arrives.

use std::io::Write;

use crate::params::CHANNELS;
use crate::{bail, ensure, err};

/// Wire magic, first 4 bytes of every frame.
pub const MAGIC: [u8; 4] = *b"HDCW";
/// Wire format version (bump on any layout change).
pub const WIRE_VERSION: u8 = 1;
/// Fixed header size: magic + version + kind + payload length.
pub const HEADER_LEN: usize = 10;
/// Hard payload cap — enforced from the header alone, so a corrupt or
/// hostile length can never size an allocation past this.
pub const MAX_PAYLOAD: usize = 1 << 20;
/// Largest multichannel sample count one `Samples` frame can carry.
pub const MAX_SAMPLES_PER_FRAME: usize = (MAX_PAYLOAD - 12) / (CHANNELS * 4);

const KIND_SUBSCRIBE: u8 = 1;
const KIND_SAMPLES: u8 = 2;
const KIND_PREDICTION: u8 = 3;
const KIND_HEARTBEAT: u8 = 4;
const KIND_SHUTDOWN: u8 = 5;
const KIND_SHARD_HELLO: u8 = 6;
const KIND_LEASE: u8 = 7;
const KIND_ROUTE: u8 = 8;
const KIND_STATUS: u8 = 9;
const KIND_STATUS_REPORT: u8 = 10;

/// One patient's retrain-loop telemetry inside a [`Frame::StatusReport`].
///
/// The FA rate travels as the exact estimator fraction (`fa_hits` false
/// alarms over the `fa_seen` outcomes currently in the sliding window)
/// instead of a float, so two same-seed runs serialize bit-identically
/// and the decoder can reject impossible payloads (`fa_hits > fa_seen`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PatientStatus {
    pub patient: u32,
    /// False alarms currently inside the FA-rate estimator window.
    pub fa_hits: u32,
    /// Outcomes currently inside the FA-rate estimator window.
    pub fa_seen: u32,
    /// Models published by the retrain loop for this patient.
    pub retrains: u32,
    /// Times the drift watch fired (≥ `retrains`: a trigger without a
    /// training source publishes nothing).
    pub triggers: u32,
    /// Labelled serving windows retained in the feedback ring.
    pub feedback_depth: u32,
}

/// One protocol frame (either direction; the server only accepts
/// client-side kinds and vice versa — direction is policed by the
/// connection actor, not the codec).
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Client → server: open a session for this patient's published model.
    Subscribe { patient: u32 },
    /// Client → server: a contiguous time-major run of multichannel
    /// samples. `seq` numbers the *frames* (0, 1, 2, …) so the server can
    /// reject gaps and reordering.
    Samples { seq: u64, samples: Vec<f32> },
    /// Server → client: one window's classification.
    Prediction {
        window: u64,
        is_ictal: bool,
        margin: i64,
        model_version: u64,
    },
    /// Either direction: liveness while no data flows.
    Heartbeat { seq: u64 },
    /// Either direction: orderly close with a reason.
    Shutdown { reason: String },
    /// Dispatcher ↔ shard: control-plane registration handshake. The
    /// dispatcher opens a control connection and announces the shard's
    /// placement slot plus its registration epoch; the shard echoes the
    /// frame back as the acknowledgement. `epoch` increments on every
    /// re-registration so a stale hello can never be mistaken for a
    /// fresh one.
    ShardHello { shard: u32, epoch: u64 },
    /// Dispatcher → shard (echoed back as the ack): a patient is leased
    /// to this shard under the given registration epoch. Leases are
    /// renewed while the session's frames flow and reaped by the
    /// dispatcher when the shard dies or the session goes silent.
    Lease { patient: u32, shard: u32, epoch: u64 },
    /// Dispatcher → client: where a `Subscribe` was placed (shard slot
    /// and its data-plane address) before the session is proxied through.
    Route {
        patient: u32,
        shard: u32,
        addr: String,
    },
    /// Client → server: ask for the serving plane's telemetry snapshot.
    /// Allowed on any connection (a scraper need not subscribe first).
    Status,
    /// Server → client: the telemetry snapshot — plane-cache counters
    /// plus one [`PatientStatus`] entry per patient the retrain loop is
    /// watching (sorted by patient id, so same-state reports serialize
    /// bit-identically).
    StatusReport {
        cache_hits: u64,
        cache_misses: u64,
        cache_evictions: u64,
        cache_redecodes: u64,
        patients: Vec<PatientStatus>,
    },
}

impl Frame {
    pub fn kind(&self) -> u8 {
        match self {
            Frame::Subscribe { .. } => KIND_SUBSCRIBE,
            Frame::Samples { .. } => KIND_SAMPLES,
            Frame::Prediction { .. } => KIND_PREDICTION,
            Frame::Heartbeat { .. } => KIND_HEARTBEAT,
            Frame::Shutdown { .. } => KIND_SHUTDOWN,
            Frame::ShardHello { .. } => KIND_SHARD_HELLO,
            Frame::Lease { .. } => KIND_LEASE,
            Frame::Route { .. } => KIND_ROUTE,
            Frame::Status => KIND_STATUS,
            Frame::StatusReport { .. } => KIND_STATUS_REPORT,
        }
    }

    pub fn kind_name(&self) -> &'static str {
        match self {
            Frame::Subscribe { .. } => "Subscribe",
            Frame::Samples { .. } => "Samples",
            Frame::Prediction { .. } => "Prediction",
            Frame::Heartbeat { .. } => "Heartbeat",
            Frame::Shutdown { .. } => "Shutdown",
            Frame::ShardHello { .. } => "ShardHello",
            Frame::Lease { .. } => "Lease",
            Frame::Route { .. } => "Route",
            Frame::Status => "Status",
            Frame::StatusReport { .. } => "StatusReport",
        }
    }

    fn payload(&self) -> Vec<u8> {
        match self {
            Frame::Subscribe { patient } => patient.to_le_bytes().to_vec(),
            Frame::Samples { seq, samples } => {
                let mut p = Vec::with_capacity(12 + samples.len() * 4);
                p.extend_from_slice(&seq.to_le_bytes());
                let n = samples.len() / CHANNELS;
                p.extend_from_slice(&(n as u32).to_le_bytes());
                for s in samples {
                    p.extend_from_slice(&s.to_bits().to_le_bytes());
                }
                p
            }
            Frame::Prediction {
                window,
                is_ictal,
                margin,
                model_version,
            } => {
                let mut p = Vec::with_capacity(25);
                p.extend_from_slice(&window.to_le_bytes());
                p.extend_from_slice(&model_version.to_le_bytes());
                p.extend_from_slice(&margin.to_le_bytes());
                p.push(*is_ictal as u8);
                p
            }
            Frame::Heartbeat { seq } => seq.to_le_bytes().to_vec(),
            Frame::Shutdown { reason } => {
                let mut p = Vec::with_capacity(4 + reason.len());
                p.extend_from_slice(&(reason.len() as u32).to_le_bytes());
                p.extend_from_slice(reason.as_bytes());
                p
            }
            Frame::ShardHello { shard, epoch } => {
                let mut p = Vec::with_capacity(12);
                p.extend_from_slice(&shard.to_le_bytes());
                p.extend_from_slice(&epoch.to_le_bytes());
                p
            }
            Frame::Lease {
                patient,
                shard,
                epoch,
            } => {
                let mut p = Vec::with_capacity(16);
                p.extend_from_slice(&patient.to_le_bytes());
                p.extend_from_slice(&shard.to_le_bytes());
                p.extend_from_slice(&epoch.to_le_bytes());
                p
            }
            Frame::Route {
                patient,
                shard,
                addr,
            } => {
                let mut p = Vec::with_capacity(12 + addr.len());
                p.extend_from_slice(&patient.to_le_bytes());
                p.extend_from_slice(&shard.to_le_bytes());
                p.extend_from_slice(&(addr.len() as u32).to_le_bytes());
                p.extend_from_slice(addr.as_bytes());
                p
            }
            Frame::Status => Vec::new(),
            Frame::StatusReport {
                cache_hits,
                cache_misses,
                cache_evictions,
                cache_redecodes,
                patients,
            } => {
                let mut p = Vec::with_capacity(36 + patients.len() * 24);
                p.extend_from_slice(&cache_hits.to_le_bytes());
                p.extend_from_slice(&cache_misses.to_le_bytes());
                p.extend_from_slice(&cache_evictions.to_le_bytes());
                p.extend_from_slice(&cache_redecodes.to_le_bytes());
                p.extend_from_slice(&(patients.len() as u32).to_le_bytes());
                for s in patients {
                    p.extend_from_slice(&s.patient.to_le_bytes());
                    p.extend_from_slice(&s.fa_hits.to_le_bytes());
                    p.extend_from_slice(&s.fa_seen.to_le_bytes());
                    p.extend_from_slice(&s.retrains.to_le_bytes());
                    p.extend_from_slice(&s.triggers.to_le_bytes());
                    p.extend_from_slice(&s.feedback_depth.to_le_bytes());
                }
                p
            }
        }
    }

    /// Serialize to header + payload. Panics only on frames the sender
    /// itself built malformed (a `Samples` run that is not a whole number
    /// of multichannel frames, or an oversize payload) — encoding never
    /// sees untrusted input.
    pub fn to_bytes(&self) -> Vec<u8> {
        if let Frame::Samples { samples, .. } = self {
            assert!(
                samples.len() % CHANNELS == 0,
                "Samples run of {} f32s is not a whole number of {CHANNELS}-channel frames",
                samples.len()
            );
            assert!(
                samples.len() / CHANNELS <= MAX_SAMPLES_PER_FRAME,
                "Samples frame of {} exceeds MAX_SAMPLES_PER_FRAME ({MAX_SAMPLES_PER_FRAME})",
                samples.len() / CHANNELS
            );
        }
        let payload = self.payload();
        assert!(payload.len() <= MAX_PAYLOAD, "payload exceeds MAX_PAYLOAD");
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        out.extend_from_slice(&MAGIC);
        out.push(WIRE_VERSION);
        out.push(self.kind());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Decode a payload whose header already passed [`FrameDecoder`]'s
    /// checks. Total: every malformed payload is an `Err`.
    pub fn decode_payload(kind: u8, payload: &[u8]) -> crate::Result<Frame> {
        let mut r = Reader {
            buf: payload,
            pos: 0,
        };
        let frame = match kind {
            KIND_SUBSCRIBE => Frame::Subscribe { patient: r.u32()? },
            KIND_SAMPLES => {
                let seq = r.u64()?;
                let n = r.u32()? as usize;
                ensure!(
                    n <= MAX_SAMPLES_PER_FRAME,
                    "Samples frame claims {n} samples (max {MAX_SAMPLES_PER_FRAME})"
                );
                let bytes = r.take(n * CHANNELS * 4)?;
                let samples = bytes
                    .chunks_exact(4)
                    .map(|b| f32::from_bits(u32::from_le_bytes([b[0], b[1], b[2], b[3]])))
                    .collect();
                Frame::Samples { seq, samples }
            }
            KIND_PREDICTION => {
                let window = r.u64()?;
                let model_version = r.u64()?;
                let margin = r.i64()?;
                let label = r.u8()?;
                ensure!(label <= 1, "Prediction label byte {label} is not 0/1");
                Frame::Prediction {
                    window,
                    is_ictal: label == 1,
                    margin,
                    model_version,
                }
            }
            KIND_HEARTBEAT => Frame::Heartbeat { seq: r.u64()? },
            KIND_SHUTDOWN => {
                let len = r.u32()? as usize;
                let bytes = r.take(len)?;
                let reason = std::str::from_utf8(bytes)
                    .map_err(|_| err!("Shutdown reason is not UTF-8"))?
                    .to_string();
                Frame::Shutdown { reason }
            }
            KIND_SHARD_HELLO => Frame::ShardHello {
                shard: r.u32()?,
                epoch: r.u64()?,
            },
            KIND_LEASE => Frame::Lease {
                patient: r.u32()?,
                shard: r.u32()?,
                epoch: r.u64()?,
            },
            KIND_ROUTE => {
                let patient = r.u32()?;
                let shard = r.u32()?;
                let len = r.u32()? as usize;
                let bytes = r.take(len)?;
                let addr = std::str::from_utf8(bytes)
                    .map_err(|_| err!("Route addr is not UTF-8"))?
                    .to_string();
                Frame::Route {
                    patient,
                    shard,
                    addr,
                }
            }
            KIND_STATUS => Frame::Status,
            KIND_STATUS_REPORT => {
                let cache_hits = r.u64()?;
                let cache_misses = r.u64()?;
                let cache_evictions = r.u64()?;
                let cache_redecodes = r.u64()?;
                let n = r.u32()? as usize;
                // No pre-allocation from the claimed count: each entry
                // consumes 24 payload bytes, so a lying `n` fails on the
                // first bounds-checked read instead of sizing a Vec.
                let mut patients = Vec::new();
                let mut prev: Option<u32> = None;
                for _ in 0..n {
                    let s = PatientStatus {
                        patient: r.u32()?,
                        fa_hits: r.u32()?,
                        fa_seen: r.u32()?,
                        retrains: r.u32()?,
                        triggers: r.u32()?,
                        feedback_depth: r.u32()?,
                    };
                    ensure!(
                        s.fa_hits <= s.fa_seen,
                        "StatusReport patient {}: {} false alarms over {} outcomes",
                        s.patient,
                        s.fa_hits,
                        s.fa_seen
                    );
                    ensure!(
                        prev.map_or(true, |p| p < s.patient),
                        "StatusReport patients are not strictly ascending at {}",
                        s.patient
                    );
                    prev = Some(s.patient);
                    patients.push(s);
                }
                Frame::StatusReport {
                    cache_hits,
                    cache_misses,
                    cache_evictions,
                    cache_redecodes,
                    patients,
                }
            }
            other => bail!("unknown frame kind {other}"),
        };
        r.finish(frame.kind_name())?;
        Ok(frame)
    }
}

/// Shared close-reason vocabulary for [`Frame::Shutdown`].
///
/// Reason strings stay human-readable, but their *class* is machine-
/// readable by prefix: every producer (the wire server's reaper, the
/// fleet dispatcher's re-lease path) builds reasons through these
/// helpers, and every consumer (the loadgen `shutdown_reasons`
/// histogram, the client's replay-on-rebalance logic) goes through
/// [`close::classify`] — so a wording tweak in the detail text can never
/// silently reclassify sessions.
pub mod close {
    /// Orderly end-of-stream (sent by both sides).
    pub const END_OF_STREAM: &str = "end of stream";
    /// Prefix of staleness closes (the reaper's cut, or a dispatcher
    /// giving up on a client that never subscribed).
    pub const STALE_PREFIX: &str = "stale";
    /// Prefix of fleet re-lease closes: the session's shard was lost
    /// mid-stream and the patient moves to a survivor on replay.
    pub const RELEASED_PREFIX: &str = "re-leased";

    /// Build a staleness reason (`"stale: <detail>"`).
    pub fn stale(detail: impl std::fmt::Display) -> String {
        format!("{STALE_PREFIX}: {detail}")
    }

    /// Build a re-lease reason (`"re-leased: <detail>"`).
    pub fn released(detail: impl std::fmt::Display) -> String {
        format!("{RELEASED_PREFIX}: {detail}")
    }

    /// Machine-readable class of a session's closing reason (`None` =
    /// the connection ended with bare EOF, the shed signature).
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum Class {
        Clean,
        Stale,
        Rebalanced,
        Shed,
        ProtocolError,
    }

    /// Classify a closing reason into its histogram bucket.
    pub fn classify(reason: Option<&str>) -> Class {
        match reason {
            None => Class::Shed,
            Some(END_OF_STREAM) => Class::Clean,
            Some(r) if r.starts_with(STALE_PREFIX) => Class::Stale,
            Some(r) if r.starts_with(RELEASED_PREFIX) => Class::Rebalanced,
            Some(_) => Class::ProtocolError,
        }
    }
}

/// Write one frame and flush it onto the wire.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> crate::Result<()> {
    w.write_all(&frame.to_bytes())
        .map_err(|e| err!("write {} frame: {e}", frame.kind_name()))?;
    w.flush().map_err(|e| err!("flush {} frame: {e}", frame.kind_name()))
}

/// Bounds-checked payload cursor (the wire twin of the bundle format's
/// reader): every read is validated against the remaining bytes, and
/// [`Self::finish`] rejects trailing garbage.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        ensure!(
            n <= self.buf.len() - self.pos,
            "payload truncated: need {n} bytes at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> crate::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> crate::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> crate::Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn i64(&mut self) -> crate::Result<i64> {
        Ok(self.u64()? as i64)
    }

    fn finish(&self, kind: &str) -> crate::Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "{kind} payload has {} trailing bytes",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

/// Incremental stream reassembler: feed arbitrary byte chunks with
/// [`Self::extend`], pull whole frames with [`Self::next_frame`].
///
/// Header validation (magic, version, payload bound) happens as soon as
/// [`HEADER_LEN`] bytes are buffered — a hostile length is rejected
/// *before* the decoder waits for (or allocates) that many bytes. After
/// an `Err` the stream is unrecoverable by design: framing is lost, the
/// connection must close.
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    pos: usize,
}

/// Compact the consumed prefix once it grows past this (amortizes the
/// memmove instead of paying it per frame).
const COMPACT_THRESHOLD: usize = 64 * 1024;

impl FrameDecoder {
    pub fn new() -> Self {
        FrameDecoder::default()
    }

    /// Buffer more stream bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed as frames.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// True at a frame boundary (no partial frame pending) — an EOF here
    /// is orderly, an EOF mid-frame is truncation.
    pub fn is_empty(&self) -> bool {
        self.buffered() == 0
    }

    /// Next whole frame, `Ok(None)` when more bytes are needed.
    pub fn next_frame(&mut self) -> crate::Result<Option<Frame>> {
        let avail = &self.buf[self.pos..];
        if avail.len() < HEADER_LEN {
            self.compact();
            return Ok(None);
        }
        ensure!(
            avail[..4] == MAGIC,
            "bad frame magic {:02x?} (stream desynchronized or not HDCW)",
            &avail[..4]
        );
        let version = avail[4];
        ensure!(
            version == WIRE_VERSION,
            "unsupported wire version {version} (this build speaks {WIRE_VERSION})"
        );
        let kind = avail[5];
        let len = u32::from_le_bytes([avail[6], avail[7], avail[8], avail[9]]) as usize;
        ensure!(
            len <= MAX_PAYLOAD,
            "frame payload length {len} exceeds the {MAX_PAYLOAD}-byte cap"
        );
        if avail.len() < HEADER_LEN + len {
            self.compact();
            return Ok(None);
        }
        let frame = Frame::decode_payload(kind, &avail[HEADER_LEN..HEADER_LEN + len])?;
        self.pos += HEADER_LEN + len;
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos >= COMPACT_THRESHOLD {
            self.compact();
        }
        Ok(Some(frame))
    }

    fn compact(&mut self) {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }
}

/// One read attempt's outcome ([`FrameReader::read`]).
pub enum ReadOutcome {
    /// A whole frame arrived.
    Frame(Frame),
    /// Orderly end of stream (at a frame boundary).
    Eof,
    /// The read timed out with no (complete) frame — the caller's chance
    /// to check deadlines and stop flags.
    Idle,
}

/// Blocking frame reader over any byte stream: couples an `io::Read`
/// with a [`FrameDecoder`], mapping timeouts to [`ReadOutcome::Idle`]
/// (so a read timeout mid-frame loses nothing — the partial bytes stay
/// buffered) and EOF-mid-frame to an error.
pub struct FrameReader<R> {
    inner: R,
    decoder: FrameDecoder,
    chunk: [u8; 4096],
}

impl<R: std::io::Read> FrameReader<R> {
    pub fn new(inner: R) -> Self {
        FrameReader {
            inner,
            decoder: FrameDecoder::new(),
            chunk: [0; 4096],
        }
    }

    /// The underlying stream (to set read timeouts).
    pub fn get_mut(&mut self) -> &mut R {
        &mut self.inner
    }

    pub fn read(&mut self) -> crate::Result<ReadOutcome> {
        loop {
            if let Some(frame) = self.decoder.next_frame()? {
                return Ok(ReadOutcome::Frame(frame));
            }
            match self.inner.read(&mut self.chunk) {
                Ok(0) => {
                    ensure!(
                        self.decoder.is_empty(),
                        "stream truncated mid-frame ({} bytes pending)",
                        self.decoder.buffered()
                    );
                    return Ok(ReadOutcome::Eof);
                }
                Ok(n) => self.decoder.extend(&self.chunk[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    return Ok(ReadOutcome::Idle);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => bail!("stream read failed: {e}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Subscribe { patient: 7 },
            Frame::Samples {
                seq: 3,
                samples: vec![0.25f32; 2 * CHANNELS],
            },
            Frame::Prediction {
                window: 41,
                is_ictal: true,
                margin: -17,
                model_version: 2,
            },
            Frame::Heartbeat { seq: 9 },
            Frame::Shutdown {
                reason: "end of stream".into(),
            },
            Frame::ShardHello { shard: 1, epoch: 4 },
            Frame::Lease {
                patient: 7,
                shard: 1,
                epoch: 4,
            },
            Frame::Route {
                patient: 7,
                shard: 1,
                addr: "127.0.0.1:7001".into(),
            },
            Frame::Status,
            Frame::StatusReport {
                cache_hits: 100,
                cache_misses: 7,
                cache_evictions: 3,
                cache_redecodes: 2,
                patients: vec![
                    PatientStatus {
                        patient: 5,
                        fa_hits: 2,
                        fa_seen: 64,
                        retrains: 1,
                        triggers: 1,
                        feedback_depth: 16,
                    },
                    PatientStatus {
                        patient: 7,
                        fa_hits: 0,
                        fa_seen: 0,
                        retrains: 0,
                        triggers: 0,
                        feedback_depth: 0,
                    },
                ],
            },
        ]
    }

    #[test]
    fn every_kind_round_trips() {
        for f in sample_frames() {
            let bytes = f.to_bytes();
            let mut d = FrameDecoder::new();
            d.extend(&bytes);
            let got = d.next_frame().unwrap().expect("whole frame buffered");
            assert_eq!(got, f, "{} round trip", f.kind_name());
            assert!(d.is_empty());
        }
    }

    #[test]
    fn decoder_reassembles_byte_by_byte() {
        let frames = sample_frames();
        let stream: Vec<u8> = frames.iter().flat_map(|f| f.to_bytes()).collect();
        let mut d = FrameDecoder::new();
        let mut got = Vec::new();
        for &b in &stream {
            d.extend(&[b]);
            while let Some(f) = d.next_frame().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got, frames);
        assert!(d.is_empty());
    }

    #[test]
    fn bad_magic_and_version_rejected() {
        let mut bytes = Frame::Heartbeat { seq: 1 }.to_bytes();
        bytes[0] ^= 0xFF;
        let mut d = FrameDecoder::new();
        d.extend(&bytes);
        assert!(d.next_frame().is_err());

        let mut bytes = Frame::Heartbeat { seq: 1 }.to_bytes();
        bytes[4] = WIRE_VERSION + 1;
        let mut d = FrameDecoder::new();
        d.extend(&bytes);
        let err = format!("{:#}", d.next_frame().unwrap_err());
        assert!(err.contains("wire version"), "{err}");
    }

    #[test]
    fn oversize_length_rejected_from_header_alone() {
        // Only the 10 header bytes arrive; the claimed payload never
        // does. The decoder must reject it immediately instead of
        // waiting for (or allocating) 4 GiB.
        let mut bytes = Frame::Heartbeat { seq: 1 }.to_bytes();
        bytes[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut d = FrameDecoder::new();
        d.extend(&bytes[..HEADER_LEN]);
        let err = format!("{:#}", d.next_frame().unwrap_err());
        assert!(err.contains("cap"), "{err}");
    }

    #[test]
    fn samples_count_must_match_payload() {
        let f = Frame::Samples {
            seq: 0,
            samples: vec![1.0; CHANNELS],
        };
        let mut bytes = f.to_bytes();
        // Claim 2 samples while carrying 1: truncated payload error.
        bytes[HEADER_LEN + 8..HEADER_LEN + 12].copy_from_slice(&2u32.to_le_bytes());
        let mut d = FrameDecoder::new();
        d.extend(&bytes);
        assert!(d.next_frame().is_err());
    }

    #[test]
    fn prediction_label_must_be_boolean() {
        let f = Frame::Prediction {
            window: 0,
            is_ictal: false,
            margin: 0,
            model_version: 1,
        };
        let mut bytes = f.to_bytes();
        *bytes.last_mut().unwrap() = 2;
        let mut d = FrameDecoder::new();
        d.extend(&bytes);
        assert!(d.next_frame().is_err());
    }

    #[test]
    fn status_report_rejects_impossible_entries() {
        let f = Frame::StatusReport {
            cache_hits: 0,
            cache_misses: 0,
            cache_evictions: 0,
            cache_redecodes: 0,
            patients: vec![PatientStatus {
                patient: 3,
                fa_hits: 1,
                fa_seen: 8,
                retrains: 0,
                triggers: 0,
                feedback_depth: 0,
            }],
        };
        // fa_hits > fa_seen is impossible for a sliding-window estimator.
        let mut bytes = f.to_bytes();
        let hits_at = HEADER_LEN + 36 + 4;
        bytes[hits_at..hits_at + 4].copy_from_slice(&9u32.to_le_bytes());
        let mut d = FrameDecoder::new();
        d.extend(&bytes);
        let err = format!("{:#}", d.next_frame().unwrap_err());
        assert!(err.contains("false alarms"), "{err}");

        // A patient count larger than the carried entries is truncation.
        let mut bytes = f.to_bytes();
        bytes[HEADER_LEN + 32..HEADER_LEN + 36].copy_from_slice(&2u32.to_le_bytes());
        let mut d = FrameDecoder::new();
        d.extend(&bytes);
        assert!(d.next_frame().is_err());

        // Entries must be strictly ascending by patient id.
        let dup = Frame::StatusReport {
            cache_hits: 0,
            cache_misses: 0,
            cache_evictions: 0,
            cache_redecodes: 0,
            patients: vec![
                PatientStatus {
                    patient: 3,
                    fa_hits: 0,
                    fa_seen: 0,
                    retrains: 0,
                    triggers: 0,
                    feedback_depth: 0,
                },
                PatientStatus {
                    patient: 3,
                    fa_hits: 0,
                    fa_seen: 0,
                    retrains: 0,
                    triggers: 0,
                    feedback_depth: 0,
                },
            ],
        };
        let mut d = FrameDecoder::new();
        d.extend(&dup.to_bytes());
        let err = format!("{:#}", d.next_frame().unwrap_err());
        assert!(err.contains("ascending"), "{err}");
    }

    #[test]
    fn unknown_kind_rejected() {
        let mut bytes = Frame::Heartbeat { seq: 1 }.to_bytes();
        bytes[5] = 99;
        let mut d = FrameDecoder::new();
        d.extend(&bytes);
        assert!(d.next_frame().is_err());
    }

    #[test]
    fn close_reasons_classify_by_prefix_not_wording() {
        use close::{classify, Class};
        assert_eq!(classify(Some(close::END_OF_STREAM)), Class::Clean);
        assert_eq!(classify(Some(&close::stale("no frames for 5 s"))), Class::Stale);
        assert_eq!(
            classify(Some(&close::released("shard 0 lost; patient 7 moves on"))),
            Class::Rebalanced
        );
        // Detail wording is free to change without reclassifying.
        assert_eq!(classify(Some("stale: totally different detail")), Class::Stale);
        assert_eq!(classify(Some("re-leased: another wording")), Class::Rebalanced);
        assert_eq!(classify(Some("Samples before Subscribe")), Class::ProtocolError);
        assert_eq!(classify(None), Class::Shed);
    }

    /// Every `Shutdown` reason string the codebase actually produces —
    /// wire.rs's connection actor, fleet.rs's dispatcher, client.rs's
    /// orderly close — lands in its intended histogram bucket. A new
    /// producer (or a reworded one) that classifies differently should
    /// change this inventory deliberately, not by accident.
    #[test]
    fn every_produced_shutdown_reason_classifies_to_its_intended_class() {
        use close::{classify, Class};
        // (producer's literal reason, intended class)
        let inventory: &[(String, Class)] = &[
            // Both sides' orderly end (wire.rs maybe_finish, client.rs
            // stream_record's closing frame).
            (close::END_OF_STREAM.into(), Class::Clean),
            // wire.rs: the staleness reaper's cut.
            (
                close::stale(format!(
                    "no frames within the {:?} staleness deadline",
                    std::time::Duration::from_secs(5)
                )),
                Class::Stale,
            ),
            // fleet.rs: a dialed client that never subscribed.
            (
                close::stale("no Subscribe within the staleness deadline"),
                Class::Stale,
            ),
            // fleet.rs: mid-stream shard loss, all three wordings.
            (
                close::released("shard 1 unreachable; patient 7 moves to a survivor"),
                Class::Rebalanced,
            ),
            (
                close::released("shard 1 lost; patient 7 moves to a survivor"),
                Class::Rebalanced,
            ),
            (
                close::released("shard 1 lost; patient 7 moves to a surviving shard"),
                Class::Rebalanced,
            ),
            // wire.rs protocol_error reasons, verbatim.
            ("protocol error: payload truncated".into(), Class::ProtocolError),
            ("Subscribe on a control connection".into(), Class::ProtocolError),
            ("duplicate Subscribe".into(), Class::ProtocolError),
            ("no model published for patient 9".into(), Class::ProtocolError),
            ("Samples before Subscribe".into(), Class::ProtocolError),
            ("Samples seq 3, expected 2".into(), Class::ProtocolError),
            (
                "client sent a server-side Prediction frame".into(),
                Class::ProtocolError,
            ),
            ("ShardHello on a data connection".into(), Class::ProtocolError),
            (
                "ShardHello for shard 2, this server is shard 0".into(),
                Class::ProtocolError,
            ),
            ("Lease on a data connection".into(), Class::ProtocolError),
            (
                "client sent a dispatcher-side Route frame".into(),
                Class::ProtocolError,
            ),
            (
                "client sent a server-side StatusReport frame".into(),
                Class::ProtocolError,
            ),
            // fleet.rs dispatcher rejections.
            ("expected Subscribe, got Samples".into(), Class::ProtocolError),
            ("no live shard for patient 7".into(), Class::ProtocolError),
        ];
        for (reason, want) in inventory {
            assert_eq!(
                classify(Some(reason)),
                *want,
                "reason {reason:?} classified off-bucket"
            );
        }
        // The shed signature is the *absence* of a reason: bare EOF.
        assert_eq!(classify(None), Class::Shed);
    }

    #[test]
    fn frame_reader_maps_eof_and_truncation() {
        let stream = Frame::Heartbeat { seq: 5 }.to_bytes();
        let mut r = FrameReader::new(std::io::Cursor::new(stream.clone()));
        assert!(matches!(r.read().unwrap(), ReadOutcome::Frame(Frame::Heartbeat { seq: 5 })));
        assert!(matches!(r.read().unwrap(), ReadOutcome::Eof));

        // EOF mid-frame is truncation, not an orderly end.
        let mut r = FrameReader::new(std::io::Cursor::new(stream[..stream.len() - 1].to_vec()));
        assert!(r.read().is_err());
    }
}
