//! Load generator: replay many concurrent patient streams against a
//! wire server and report throughput / latency / drop counts
//! (`repro loadgen`, and the CI scale smoke).
//!
//! A fixed worker pool pulls session indices off a shared counter until
//! `sessions` streams have run — so "2000 sessions over 32 workers" is
//! 2000 sequential-per-worker streams with 32 in flight at any moment,
//! the same discipline the evalpool uses for sweeps. Each stream is a
//! full client session ([`stream_record`]): subscribe, chunked samples,
//! drain predictions, orderly shutdown.
//!
//! The report is a versioned `loadgen/v1` JSON document (same
//! schema-tag discipline as `benchkit/v1`), diffable across runs with
//! `repro loadgen-diff`. A committed baseline with `"sessions": 0` is
//! the "no baseline yet" stub — the diff refuses it; promote a real
//! report over it first (`scripts/promote-bench-baselines.sh`).

use std::sync::atomic::{AtomicUsize, Ordering::Relaxed};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::benchkit::JsonScanner;
use crate::ensure;
use crate::transport::client::{stream_record, StreamClientConfig};
use crate::transport::Duplex;

/// Load-run shape.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Total streamed sessions.
    pub sessions: usize,
    /// Worker threads (sessions in flight at once).
    pub concurrency: usize,
    pub client: StreamClientConfig,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            sessions: 64,
            concurrency: 16,
            client: StreamClientConfig::default(),
        }
    }
}

/// Aggregated outcome of one load run (`loadgen/v1`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LoadgenReport {
    /// Sessions that ran to an orderly end-of-stream shutdown.
    pub sessions: u64,
    /// Sessions that errored or were cut off (shed, stale, EOF).
    pub failures: u64,
    pub windows_sent: u64,
    /// Predictions received back.
    pub windows: u64,
    /// Windows never answered (`windows_sent - windows`).
    pub drops: u64,
    pub heartbeats: u64,
    pub elapsed_s: f64,
    /// Predictions received per wall-clock second.
    pub windows_per_s: f64,
    /// Window-on-wire → prediction-read latency percentiles; `None`
    /// until any prediction arrives.
    pub p50_latency_s: Option<f64>,
    pub p95_latency_s: Option<f64>,
}

impl LoadgenReport {
    pub fn summary(&self) -> String {
        let lat = |v: Option<f64>| match v {
            Some(s) => format!("{:.2} ms", s * 1e3),
            None => "—".to_string(),
        };
        format!(
            "{} sessions ({} failed) | {}/{} windows answered, {} dropped | \
             {:.0} windows/s | p50 {} p95 {} | {} heartbeats | {:.2} s",
            self.sessions,
            self.failures,
            self.windows,
            self.windows_sent,
            self.drops,
            self.windows_per_s,
            lat(self.p50_latency_s),
            lat(self.p95_latency_s),
            self.heartbeats,
            self.elapsed_s
        )
    }

    /// Serialize as a `loadgen/v1` document.
    pub fn to_json(&self) -> String {
        let num = |v: Option<f64>| match v {
            Some(s) => format!("{s:.9}"),
            None => "null".to_string(),
        };
        format!(
            "{{\n  \"schema\": \"loadgen/v1\",\n  \"sessions\": {},\n  \"failures\": {},\n  \
             \"windows_sent\": {},\n  \"windows\": {},\n  \"drops\": {},\n  \
             \"heartbeats\": {},\n  \"elapsed_s\": {:.6},\n  \"windows_per_s\": {:.3},\n  \
             \"p50_latency_s\": {},\n  \"p95_latency_s\": {}\n}}\n",
            self.sessions,
            self.failures,
            self.windows_sent,
            self.windows,
            self.drops,
            self.heartbeats,
            self.elapsed_s,
            self.windows_per_s,
            num(self.p50_latency_s),
            num(self.p95_latency_s),
        )
    }
}

/// Parse a `loadgen/v1` document back (for `repro loadgen-diff` and the
/// CI gate).
pub fn parse_loadgen_json(text: &str) -> crate::Result<LoadgenReport> {
    let mut scanner = JsonScanner::new(text);
    let mut schema = None;
    let mut report = LoadgenReport::default();
    scanner.object(|s, key| {
        match key {
            "schema" => schema = Some(s.string()?),
            "sessions" => report.sessions = s.value()?.unwrap_or(0.0) as u64,
            "failures" => report.failures = s.value()?.unwrap_or(0.0) as u64,
            "windows_sent" => report.windows_sent = s.value()?.unwrap_or(0.0) as u64,
            "windows" => report.windows = s.value()?.unwrap_or(0.0) as u64,
            "drops" => report.drops = s.value()?.unwrap_or(0.0) as u64,
            "heartbeats" => report.heartbeats = s.value()?.unwrap_or(0.0) as u64,
            "elapsed_s" => report.elapsed_s = s.value()?.unwrap_or(0.0),
            "windows_per_s" => report.windows_per_s = s.value()?.unwrap_or(0.0),
            "p50_latency_s" => report.p50_latency_s = s.value()?,
            "p95_latency_s" => report.p95_latency_s = s.value()?,
            _ => {
                s.value()?; // forward-compatible: skip unknown fields
            }
        }
        Ok(())
    })?;
    ensure!(
        schema.as_deref() == Some("loadgen/v1"),
        "not a loadgen/v1 document (schema {schema:?})"
    );
    Ok(report)
}

/// A committed baseline that has never been refreshed from a real run
/// (the `"sessions": 0` stub): `repro loadgen-diff` refuses to gate
/// against it — promote a real report in its place first.
pub fn is_stub_report(report: &LoadgenReport) -> bool {
    report.sessions == 0
}

fn percentile(sorted: &[f64], q: f64) -> Option<f64> {
    if sorted.is_empty() {
        return None;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    Some(sorted[idx.min(sorted.len() - 1)])
}

/// Run the load: `cfg.sessions` streams of `records` (round-robin by
/// session index) over connections from `connect`, `cfg.concurrency` in
/// flight. `connect` is called once per session, from worker threads.
pub fn run(
    connect: &(dyn Fn() -> crate::Result<Duplex> + Sync),
    records: &[(u32, Vec<f32>)],
    cfg: &LoadgenConfig,
) -> crate::Result<LoadgenReport> {
    ensure!(!records.is_empty(), "loadgen needs at least one record");
    ensure!(cfg.sessions > 0, "loadgen needs at least one session");
    let next = AtomicUsize::new(0);
    let agg = Mutex::new((LoadgenReport::default(), Vec::<Duration>::new()));
    let workers = cfg.concurrency.clamp(1, cfg.sessions);
    let started = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut ok = 0u64;
                let mut failed = 0u64;
                let mut windows_sent = 0u64;
                let mut windows = 0u64;
                let mut heartbeats = 0u64;
                let mut latencies = Vec::new();
                loop {
                    let i = next.fetch_add(1, Relaxed);
                    if i >= cfg.sessions {
                        break;
                    }
                    let (patient, samples) = &records[i % records.len()];
                    let outcome = connect()
                        .and_then(|conn| stream_record(conn, *patient, samples, &cfg.client));
                    match outcome {
                        Ok(o) => {
                            // Orderly end = the server's final Shutdown
                            // with no mid-stream write failure.
                            if o.shutdown_reason.is_some() && o.send_error.is_none() {
                                ok += 1;
                            } else {
                                failed += 1;
                            }
                            windows_sent += o.windows_sent;
                            windows += o.predictions.len() as u64;
                            heartbeats += o.heartbeats;
                            latencies.extend(o.latencies);
                        }
                        Err(_) => failed += 1,
                    }
                }
                let mut agg = agg.lock().expect("loadgen aggregate lock");
                agg.0.sessions += ok;
                agg.0.failures += failed;
                agg.0.windows_sent += windows_sent;
                agg.0.windows += windows;
                agg.0.heartbeats += heartbeats;
                agg.1.extend(latencies);
            });
        }
    });
    let elapsed = started.elapsed().as_secs_f64();
    let (mut report, latencies) = agg.into_inner().map_err(|_| crate::err!("worker panicked"))?;
    let mut secs: Vec<f64> = latencies.iter().map(|d| d.as_secs_f64()).collect();
    secs.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    report.drops = report.windows_sent.saturating_sub(report.windows);
    report.elapsed_s = elapsed;
    report.windows_per_s = if elapsed > 0.0 {
        report.windows as f64 / elapsed
    } else {
        0.0
    };
    report.p50_latency_s = percentile(&secs, 0.50);
    report.p95_latency_s = percentile(&secs, 0.95);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_round_trips() {
        let report = LoadgenReport {
            sessions: 64,
            failures: 1,
            windows_sent: 1792,
            windows: 1764,
            drops: 28,
            heartbeats: 3,
            elapsed_s: 2.5,
            windows_per_s: 705.6,
            p50_latency_s: Some(0.0021),
            p95_latency_s: Some(0.0134),
        };
        let parsed = parse_loadgen_json(&report.to_json()).unwrap();
        assert_eq!(parsed.sessions, 64);
        assert_eq!(parsed.failures, 1);
        assert_eq!(parsed.windows_sent, 1792);
        assert_eq!(parsed.windows, 1764);
        assert_eq!(parsed.drops, 28);
        assert_eq!(parsed.heartbeats, 3);
        assert!((parsed.elapsed_s - 2.5).abs() < 1e-9);
        assert!((parsed.windows_per_s - 705.6).abs() < 1e-6);
        assert!((parsed.p50_latency_s.unwrap() - 0.0021).abs() < 1e-12);
        assert!((parsed.p95_latency_s.unwrap() - 0.0134).abs() < 1e-12);
    }

    #[test]
    fn null_latencies_round_trip_and_stub_detected() {
        let report = LoadgenReport::default();
        let text = report.to_json();
        assert!(text.contains("\"p95_latency_s\": null"), "{text}");
        let parsed = parse_loadgen_json(&text).unwrap();
        assert_eq!(parsed.p50_latency_s, None);
        assert_eq!(parsed.p95_latency_s, None);
        assert!(is_stub_report(&parsed));
        assert!(!is_stub_report(&LoadgenReport {
            sessions: 1,
            ..Default::default()
        }));
    }

    #[test]
    fn wrong_schema_rejected() {
        let err = parse_loadgen_json("{\"schema\": \"benchkit/v1\", \"records\": []}");
        assert!(err.is_err());
    }

    #[test]
    fn unknown_fields_are_skipped() {
        let text = "{\"schema\": \"loadgen/v1\", \"sessions\": 3, \
                    \"future_field\": {\"nested\": [1, 2]}, \"windows\": 9}";
        let parsed = parse_loadgen_json(text).unwrap();
        assert_eq!(parsed.sessions, 3);
        assert_eq!(parsed.windows, 9);
    }

    #[test]
    fn percentiles_pick_from_sorted_tail() {
        let sorted: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&sorted, 0.50), Some(51.0));
        assert_eq!(percentile(&sorted, 0.95), Some(95.0));
        assert_eq!(percentile(&[], 0.95), None);
        assert_eq!(percentile(&[7.0], 0.95), Some(7.0));
    }
}
