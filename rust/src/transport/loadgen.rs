//! Load generator: replay many concurrent patient streams against a
//! wire server and report throughput / latency / drop counts
//! (`repro loadgen`, and the CI scale smoke).
//!
//! A fixed worker pool pulls session indices off a shared counter until
//! `sessions` streams have run — so "2000 sessions over 32 workers" is
//! 2000 sequential-per-worker streams with 32 in flight at any moment,
//! the same discipline the evalpool uses for sweeps. Each stream is a
//! full client session ([`stream_record`]): subscribe, chunked samples,
//! drain predictions, orderly shutdown.
//!
//! The report is a versioned `loadgen/v1` JSON document (same
//! schema-tag discipline as `benchkit/v1`), diffable across runs with
//! `repro loadgen-diff`. A committed baseline with `"sessions": 0` is
//! the "no baseline yet" stub — the diff refuses it; promote a real
//! report over it first (`scripts/promote-bench-baselines.sh`).

use std::sync::atomic::{AtomicUsize, Ordering::Relaxed};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::benchkit::JsonScanner;
use crate::ensure;
use crate::testkit::hostile;
use crate::transport::client::{stream_record, StreamClientConfig};
use crate::transport::frame::close;
use crate::transport::Duplex;

/// Load-run shape.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Total streamed sessions.
    pub sessions: usize,
    /// Worker threads (sessions in flight at once).
    pub concurrency: usize,
    /// Re-run a session this many times when a fleet dispatcher closes
    /// it with a "re-leased" `Shutdown` (a shard died mid-stream and its
    /// patients moved to survivors). The retried attempt replays the
    /// whole record, and only the final attempt is counted — safe
    /// because per-window outputs are idempotent and every shard serves
    /// the same published model version. `0` = fail like any other cut.
    pub retries: usize,
    /// Hostile-stream fault injection (`--hostile dropout,drift
    /// --seed N`): each session corrupts its own clone of the record
    /// with these injectors, re-keyed per session index from the master
    /// seed ([`hostile::session_seed`]) — two same-seed runs replay
    /// bit-identical corruption. `None` = clean streams.
    pub hostile: Option<hostile::HostileStream>,
    pub client: StreamClientConfig,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            sessions: 64,
            concurrency: 16,
            retries: 0,
            hostile: None,
            client: StreamClientConfig::default(),
        }
    }
}

/// How sessions ended, bucketed for the `shutdown_reasons` histogram in
/// `loadgen/v1` reports. Buckets follow the machine-readable close
/// classes of [`close::classify`] — the shared vocabulary every
/// `Shutdown` producer builds reasons with — so a wording change in a
/// reason's detail text can never silently reclassify sessions:
/// orderly end-of-stream is `clean`, the staleness reaper's cut is
/// `stale`, a fleet re-lease close (shard lost mid-stream, retries
/// exhausted) is `rebalanced`, any other reasoned close is
/// `protocol_error`, a connection that ended with bare EOF (the
/// slow-consumer shed path, or a crashed peer) is `shed`, and a dial
/// that never produced a connection at all is `connect_error`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShutdownReasons {
    pub clean: u64,
    pub stale: u64,
    pub shed: u64,
    pub rebalanced: u64,
    pub protocol_error: u64,
    pub connect_error: u64,
}

impl ShutdownReasons {
    /// All buckets summed — equals `sessions + failures` on reports
    /// written by a binary that has the histogram.
    pub fn total(&self) -> u64 {
        self.clean
            + self.stale
            + self.shed
            + self.rebalanced
            + self.protocol_error
            + self.connect_error
    }

    /// Bucket one session's closing reason (`None` = bare EOF).
    fn bucket(&mut self, reason: Option<&str>) {
        match close::classify(reason) {
            close::Class::Clean => self.clean += 1,
            close::Class::Stale => self.stale += 1,
            close::Class::Shed => self.shed += 1,
            close::Class::Rebalanced => self.rebalanced += 1,
            close::Class::ProtocolError => self.protocol_error += 1,
        }
    }

    /// The dial itself failed: no connection, no server close.
    fn connect_failure(&mut self) {
        self.connect_error += 1;
    }
}

/// Aggregated outcome of one load run (`loadgen/v1`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LoadgenReport {
    /// Sessions that ran to an orderly end-of-stream shutdown.
    pub sessions: u64,
    /// Sessions that errored or were cut off (shed, stale, EOF).
    pub failures: u64,
    pub windows_sent: u64,
    /// Predictions received back.
    pub windows: u64,
    /// Windows never answered (`windows_sent - windows`).
    pub drops: u64,
    pub heartbeats: u64,
    pub elapsed_s: f64,
    /// Predictions received per wall-clock second.
    pub windows_per_s: f64,
    /// Window-on-wire → prediction-read latency percentiles; `None`
    /// until any prediction arrives.
    pub p50_latency_s: Option<f64>,
    pub p95_latency_s: Option<f64>,
    /// Per-session closing-reason histogram. Sums to
    /// `sessions + failures` on reports written by this binary; all-zero
    /// on reports from before the field existed (old reports still
    /// parse — the buckets just default to 0).
    pub shutdown_reasons: ShutdownReasons,
    /// Sessions that were re-run after a fleet dispatcher's "re-leased"
    /// `Shutdown` (shard died mid-stream). Each retry's aborted attempt
    /// is discarded; only final attempts are counted above.
    pub retries: u64,
}

impl LoadgenReport {
    pub fn summary(&self) -> String {
        let lat = |v: Option<f64>| match v {
            Some(s) => format!("{:.2} ms", s * 1e3),
            None => "—".to_string(),
        };
        format!(
            "{} sessions ({} failed) | {}/{} windows answered, {} dropped | \
             {:.0} windows/s | p50 {} p95 {} | {} heartbeats | \
             ends: {} clean / {} stale / {} shed / {} rebalanced / {} protocol / \
             {} connect | {} retries | {:.2} s",
            self.sessions,
            self.failures,
            self.windows,
            self.windows_sent,
            self.drops,
            self.windows_per_s,
            lat(self.p50_latency_s),
            lat(self.p95_latency_s),
            self.heartbeats,
            self.shutdown_reasons.clean,
            self.shutdown_reasons.stale,
            self.shutdown_reasons.shed,
            self.shutdown_reasons.rebalanced,
            self.shutdown_reasons.protocol_error,
            self.shutdown_reasons.connect_error,
            self.retries,
            self.elapsed_s
        )
    }

    /// Serialize as a `loadgen/v1` document.
    pub fn to_json(&self) -> String {
        let num = |v: Option<f64>| match v {
            Some(s) => format!("{s:.9}"),
            None => "null".to_string(),
        };
        format!(
            "{{\n  \"schema\": \"loadgen/v1\",\n  \"sessions\": {},\n  \"failures\": {},\n  \
             \"windows_sent\": {},\n  \"windows\": {},\n  \"drops\": {},\n  \
             \"heartbeats\": {},\n  \"elapsed_s\": {:.6},\n  \"windows_per_s\": {:.3},\n  \
             \"p50_latency_s\": {},\n  \"p95_latency_s\": {},\n  \
             \"shutdown_reasons\": {{\"clean\": {}, \"stale\": {}, \"shed\": {}, \
             \"rebalanced\": {}, \"protocol_error\": {}, \"connect_error\": {}}},\n  \
             \"retries\": {}\n}}\n",
            self.sessions,
            self.failures,
            self.windows_sent,
            self.windows,
            self.drops,
            self.heartbeats,
            self.elapsed_s,
            self.windows_per_s,
            num(self.p50_latency_s),
            num(self.p95_latency_s),
            self.shutdown_reasons.clean,
            self.shutdown_reasons.stale,
            self.shutdown_reasons.shed,
            self.shutdown_reasons.rebalanced,
            self.shutdown_reasons.protocol_error,
            self.shutdown_reasons.connect_error,
            self.retries,
        )
    }
}

/// Parse a `loadgen/v1` document back (for `repro loadgen-diff` and the
/// CI gate).
pub fn parse_loadgen_json(text: &str) -> crate::Result<LoadgenReport> {
    let mut scanner = JsonScanner::new(text);
    let mut schema = None;
    let mut report = LoadgenReport::default();
    scanner.object(|s, key| {
        match key {
            "schema" => schema = Some(s.string()?),
            "sessions" => report.sessions = s.value()?.unwrap_or(0.0) as u64,
            "failures" => report.failures = s.value()?.unwrap_or(0.0) as u64,
            "windows_sent" => report.windows_sent = s.value()?.unwrap_or(0.0) as u64,
            "windows" => report.windows = s.value()?.unwrap_or(0.0) as u64,
            "drops" => report.drops = s.value()?.unwrap_or(0.0) as u64,
            "heartbeats" => report.heartbeats = s.value()?.unwrap_or(0.0) as u64,
            "elapsed_s" => report.elapsed_s = s.value()?.unwrap_or(0.0),
            "windows_per_s" => report.windows_per_s = s.value()?.unwrap_or(0.0),
            "p50_latency_s" => report.p50_latency_s = s.value()?,
            "p95_latency_s" => report.p95_latency_s = s.value()?,
            "shutdown_reasons" => {
                let buckets = &mut report.shutdown_reasons;
                s.object(|s, bucket| {
                    match bucket {
                        "clean" => buckets.clean = s.value()?.unwrap_or(0.0) as u64,
                        "stale" => buckets.stale = s.value()?.unwrap_or(0.0) as u64,
                        "shed" => buckets.shed = s.value()?.unwrap_or(0.0) as u64,
                        "rebalanced" => buckets.rebalanced = s.value()?.unwrap_or(0.0) as u64,
                        "protocol_error" => {
                            buckets.protocol_error = s.value()?.unwrap_or(0.0) as u64
                        }
                        "connect_error" => {
                            buckets.connect_error = s.value()?.unwrap_or(0.0) as u64
                        }
                        _ => {
                            s.value()?;
                        }
                    }
                    Ok(())
                })?;
            }
            "retries" => report.retries = s.value()?.unwrap_or(0.0) as u64,
            _ => {
                s.value()?; // forward-compatible: skip unknown fields
            }
        }
        Ok(())
    })?;
    ensure!(
        schema.as_deref() == Some("loadgen/v1"),
        "not a loadgen/v1 document (schema {schema:?})"
    );
    Ok(report)
}

/// A committed baseline that has never been refreshed from a real run
/// (the `"sessions": 0` stub): `repro loadgen-diff` refuses to gate
/// against it — promote a real report in its place first.
pub fn is_stub_report(report: &LoadgenReport) -> bool {
    report.sessions == 0
}

fn percentile(sorted: &[f64], q: f64) -> Option<f64> {
    if sorted.is_empty() {
        return None;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    Some(sorted[idx.min(sorted.len() - 1)])
}

/// Run the load: `cfg.sessions` streams of `records` (round-robin by
/// session index) over connections from `connect`, `cfg.concurrency` in
/// flight. `connect` is called once per session, from worker threads.
pub fn run(
    connect: &(dyn Fn() -> crate::Result<Duplex> + Sync),
    records: &[(u32, Vec<f32>)],
    cfg: &LoadgenConfig,
) -> crate::Result<LoadgenReport> {
    ensure!(!records.is_empty(), "loadgen needs at least one record");
    ensure!(cfg.sessions > 0, "loadgen needs at least one session");
    let next = AtomicUsize::new(0);
    let agg = Mutex::new((LoadgenReport::default(), Vec::<Duration>::new()));
    let workers = cfg.concurrency.clamp(1, cfg.sessions);
    let started = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut ok = 0u64;
                let mut failed = 0u64;
                let mut windows_sent = 0u64;
                let mut windows = 0u64;
                let mut heartbeats = 0u64;
                let mut retries = 0u64;
                let mut reasons = ShutdownReasons::default();
                let mut latencies = Vec::new();
                loop {
                    let i = next.fetch_add(1, Relaxed);
                    if i >= cfg.sessions {
                        break;
                    }
                    let (patient, samples) = &records[i % records.len()];
                    // Hostile runs corrupt a per-session clone, keyed by
                    // session index off the master seed: retries of the
                    // same session replay the identical corruption, and
                    // two same-seed runs are bit-identical end to end.
                    let corrupted: Option<Vec<f32>> = cfg.hostile.as_ref().map(|h| {
                        let mut samples = samples.clone();
                        let session = hostile::HostileStream {
                            seed: hostile::session_seed(h.seed, i as u64),
                            injectors: h.injectors.clone(),
                        };
                        session.corrupt(&mut samples);
                        samples
                    });
                    let samples = corrupted.as_ref().unwrap_or(samples);
                    let mut attempts_left = cfg.retries;
                    // `None` = the dial itself failed (its own bucket);
                    // `Some(Err)` = the stream collapsed without any
                    // server close (bucketed with the bare-EOF sheds).
                    let outcome = loop {
                        let outcome = match connect() {
                            Ok(conn) => {
                                Some(stream_record(conn, *patient, samples, &cfg.client))
                            }
                            Err(_) => None,
                        };
                        // A dispatcher cutting a session because its
                        // shard died closes with a re-lease reason; the
                        // re-run replays the whole record against the
                        // survivor and the aborted attempt is discarded
                        // (idempotent per-window outputs).
                        if attempts_left > 0
                            && matches!(&outcome, Some(Ok(o)) if close::classify(
                                o.shutdown_reason.as_deref()
                            ) == close::Class::Rebalanced)
                        {
                            attempts_left -= 1;
                            retries += 1;
                            continue;
                        }
                        break outcome;
                    };
                    match outcome {
                        Some(Ok(o)) => {
                            // Orderly end = the server's final Shutdown
                            // with no mid-stream write failure.
                            if o.shutdown_reason.is_some() && o.send_error.is_none() {
                                ok += 1;
                            } else {
                                failed += 1;
                            }
                            reasons.bucket(o.shutdown_reason.as_deref());
                            windows_sent += o.windows_sent;
                            windows += o.predictions.len() as u64;
                            heartbeats += o.heartbeats;
                            latencies.extend(o.latencies);
                        }
                        Some(Err(_)) => {
                            failed += 1;
                            reasons.bucket(None);
                        }
                        None => {
                            failed += 1;
                            reasons.connect_failure();
                        }
                    }
                }
                let mut agg = agg.lock().expect("loadgen aggregate lock");
                agg.0.sessions += ok;
                agg.0.failures += failed;
                agg.0.windows_sent += windows_sent;
                agg.0.windows += windows;
                agg.0.heartbeats += heartbeats;
                agg.0.retries += retries;
                agg.0.shutdown_reasons.clean += reasons.clean;
                agg.0.shutdown_reasons.stale += reasons.stale;
                agg.0.shutdown_reasons.shed += reasons.shed;
                agg.0.shutdown_reasons.rebalanced += reasons.rebalanced;
                agg.0.shutdown_reasons.protocol_error += reasons.protocol_error;
                agg.0.shutdown_reasons.connect_error += reasons.connect_error;
                agg.1.extend(latencies);
            });
        }
    });
    let elapsed = started.elapsed().as_secs_f64();
    let (mut report, latencies) = agg.into_inner().map_err(|_| crate::err!("worker panicked"))?;
    let mut secs: Vec<f64> = latencies.iter().map(|d| d.as_secs_f64()).collect();
    secs.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    report.drops = report.windows_sent.saturating_sub(report.windows);
    report.elapsed_s = elapsed;
    report.windows_per_s = if elapsed > 0.0 {
        report.windows as f64 / elapsed
    } else {
        0.0
    };
    report.p50_latency_s = percentile(&secs, 0.50);
    report.p95_latency_s = percentile(&secs, 0.95);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_round_trips() {
        let report = LoadgenReport {
            sessions: 64,
            failures: 1,
            windows_sent: 1792,
            windows: 1764,
            drops: 28,
            heartbeats: 3,
            elapsed_s: 2.5,
            windows_per_s: 705.6,
            p50_latency_s: Some(0.0021),
            p95_latency_s: Some(0.0134),
            shutdown_reasons: ShutdownReasons {
                clean: 64,
                stale: 0,
                shed: 1,
                rebalanced: 2,
                protocol_error: 0,
                connect_error: 3,
            },
            retries: 2,
        };
        let parsed = parse_loadgen_json(&report.to_json()).unwrap();
        assert_eq!(parsed.sessions, 64);
        assert_eq!(parsed.failures, 1);
        assert_eq!(parsed.windows_sent, 1792);
        assert_eq!(parsed.windows, 1764);
        assert_eq!(parsed.drops, 28);
        assert_eq!(parsed.heartbeats, 3);
        assert!((parsed.elapsed_s - 2.5).abs() < 1e-9);
        assert!((parsed.windows_per_s - 705.6).abs() < 1e-6);
        assert!((parsed.p50_latency_s.unwrap() - 0.0021).abs() < 1e-12);
        assert!((parsed.p95_latency_s.unwrap() - 0.0134).abs() < 1e-12);
        assert_eq!(parsed.shutdown_reasons, report.shutdown_reasons);
        assert_eq!(parsed.shutdown_reasons.total(), 70);
        assert_eq!(parsed.retries, 2);
    }

    #[test]
    fn old_reports_without_the_histogram_still_parse() {
        // A loadgen/v1 document from before `shutdown_reasons` /
        // `retries` existed: the new fields default to zero and nothing
        // else shifts.
        let text = "{\n  \"schema\": \"loadgen/v1\",\n  \"sessions\": 64,\n  \
                    \"failures\": 0,\n  \"windows_sent\": 1792,\n  \"windows\": 1792,\n  \
                    \"drops\": 0,\n  \"heartbeats\": 0,\n  \"elapsed_s\": 2.0,\n  \
                    \"windows_per_s\": 896.0,\n  \"p50_latency_s\": 0.002,\n  \
                    \"p95_latency_s\": 0.010\n}\n";
        let parsed = parse_loadgen_json(text).unwrap();
        assert_eq!(parsed.sessions, 64);
        assert_eq!(parsed.shutdown_reasons, ShutdownReasons::default());
        assert_eq!(parsed.retries, 0);
    }

    #[test]
    fn shutdown_reasons_bucket_by_close_class() {
        let mut reasons = ShutdownReasons::default();
        reasons.bucket(Some(close::END_OF_STREAM));
        reasons.bucket(Some(&close::stale("no frames within the 5s staleness deadline")));
        reasons.bucket(Some("Samples before Subscribe"));
        reasons.bucket(Some(&close::released(
            "shard 0 lost; patient 7 moves to a surviving shard",
        )));
        reasons.bucket(None);
        reasons.connect_failure();
        assert_eq!(reasons.clean, 1);
        assert_eq!(reasons.stale, 1);
        assert_eq!(reasons.protocol_error, 1);
        assert_eq!(reasons.rebalanced, 1);
        assert_eq!(reasons.shed, 1);
        assert_eq!(reasons.connect_error, 1);
        assert_eq!(reasons.total(), 6);
    }

    #[test]
    fn null_latencies_round_trip_and_stub_detected() {
        let report = LoadgenReport::default();
        let text = report.to_json();
        assert!(text.contains("\"p95_latency_s\": null"), "{text}");
        let parsed = parse_loadgen_json(&text).unwrap();
        assert_eq!(parsed.p50_latency_s, None);
        assert_eq!(parsed.p95_latency_s, None);
        assert!(is_stub_report(&parsed));
        assert!(!is_stub_report(&LoadgenReport {
            sessions: 1,
            ..Default::default()
        }));
    }

    #[test]
    fn wrong_schema_rejected() {
        let err = parse_loadgen_json("{\"schema\": \"benchkit/v1\", \"records\": []}");
        assert!(err.is_err());
    }

    #[test]
    fn unknown_fields_are_skipped() {
        let text = "{\"schema\": \"loadgen/v1\", \"sessions\": 3, \
                    \"future_field\": {\"nested\": [1, 2]}, \"windows\": 9}";
        let parsed = parse_loadgen_json(text).unwrap();
        assert_eq!(parsed.sessions, 3);
        assert_eq!(parsed.windows, 9);
    }

    #[test]
    fn percentiles_pick_from_sorted_tail() {
        let sorted: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&sorted, 0.50), Some(51.0));
        assert_eq!(percentile(&sorted, 0.95), Some(95.0));
        assert_eq!(percentile(&[], 0.95), None);
        assert_eq!(percentile(&[7.0], 0.95), Some(7.0));
    }

    #[test]
    fn percentile_edge_cases_never_panic() {
        // Empty input: every quantile is None.
        for q in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(percentile(&[], q), None);
        }
        // Single sample: every quantile is that sample.
        for q in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(percentile(&[3.25], q), Some(3.25));
        }
        // Two samples: the midpoint rounds to the upper sample, the
        // extremes clamp in range (index stays within bounds).
        assert_eq!(percentile(&[1.0, 2.0], 0.0), Some(1.0));
        assert_eq!(percentile(&[1.0, 2.0], 0.5), Some(2.0));
        assert_eq!(percentile(&[1.0, 2.0], 1.0), Some(2.0));
        // Out-of-range quantiles clamp instead of indexing past the
        // slice.
        assert_eq!(percentile(&[1.0, 2.0, 3.0], 1.5), Some(3.0));
        assert_eq!(percentile(&[1.0, 2.0, 3.0], -0.5), Some(1.0));
    }
}
