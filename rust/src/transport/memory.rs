//! In-memory transport: bounded byte pipes over `mpsc`, for
//! deterministic wire tests with no sockets.
//!
//! [`MemoryTransport::new`] returns the acceptor plus a cloneable
//! [`MemoryConnector`]; each `connect` builds two bounded byte pipes
//! (one per direction) and hands the server its half through the accept
//! queue. The pipes deliberately mimic the failure modes the TCP path
//! has: reads honour a timeout (mapping to `WouldBlock`, which the frame
//! reader surfaces as an idle tick), and writes to a peer that stopped
//! draining error out after a bounded wait instead of stalling the
//! writer forever — that error is exactly how the server detects a dead
//! consumer.

use std::io::{Read, Write};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError,
};
use std::time::{Duration, Instant};

use super::{Duplex, Transport, WireRead, WireWrite};
use crate::err;

/// Write chunks a pipe buffers before the writer blocks (then errors
/// after its write timeout). Small enough that a stalled reader is
/// detected quickly in tests, large enough that a healthy reader never
/// notices.
const DEFAULT_PIPE_DEPTH: usize = 64;
/// How long a pipe write waits on a full pipe before declaring the peer
/// dead.
const DEFAULT_WRITE_TIMEOUT: Duration = Duration::from_secs(2);

/// Read half of a byte pipe.
pub struct PipeReader {
    rx: Receiver<Vec<u8>>,
    pending: Vec<u8>,
    pos: usize,
    timeout: Option<Duration>,
}

impl Read for PipeReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        while self.pos >= self.pending.len() {
            let chunk = match self.timeout {
                None => match self.rx.recv() {
                    Ok(c) => c,
                    Err(_) => return Ok(0), // writer gone: EOF
                },
                Some(t) => match self.rx.recv_timeout(t) {
                    Ok(c) => c,
                    Err(RecvTimeoutError::Timeout) => {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::WouldBlock,
                            "pipe read timed out",
                        ));
                    }
                    Err(RecvTimeoutError::Disconnected) => return Ok(0),
                },
            };
            self.pending = chunk;
            self.pos = 0;
        }
        let n = buf.len().min(self.pending.len() - self.pos);
        buf[..n].copy_from_slice(&self.pending[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

impl WireRead for PipeReader {
    fn set_read_timeout(&mut self, timeout: Option<Duration>) -> crate::Result<()> {
        self.timeout = timeout;
        Ok(())
    }
}

/// Write half of a byte pipe (bounded: blocks briefly on a full pipe,
/// then errors — the in-memory analogue of a TCP write timeout).
pub struct PipeWriter {
    tx: SyncSender<Vec<u8>>,
    write_timeout: Duration,
}

impl Write for PipeWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        let mut chunk = buf.to_vec();
        let t0 = Instant::now();
        loop {
            match self.tx.try_send(chunk) {
                Ok(()) => return Ok(buf.len()),
                Err(TrySendError::Full(c)) => {
                    if t0.elapsed() >= self.write_timeout {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            "pipe write timed out (peer not draining)",
                        ));
                    }
                    chunk = c;
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(TrySendError::Disconnected(_)) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::BrokenPipe,
                        "pipe peer closed",
                    ));
                }
            }
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl WireWrite for PipeWriter {}

fn byte_pipe(depth: usize, write_timeout: Duration) -> (PipeWriter, PipeReader) {
    let (tx, rx) = sync_channel(depth.max(1));
    (
        PipeWriter { tx, write_timeout },
        PipeReader {
            rx,
            pending: Vec::new(),
            pos: 0,
            timeout: None,
        },
    )
}

/// Build a connected duplex pair `(client, server)` over two byte pipes.
pub fn duplex_pair(depth: usize, write_timeout: Duration) -> (Duplex, Duplex) {
    let (c2s_w, c2s_r) = byte_pipe(depth, write_timeout);
    let (s2c_w, s2c_r) = byte_pipe(depth, write_timeout);
    let client = Duplex::new(Box::new(s2c_r), Box::new(c2s_w), "memory:server".into());
    let server = Duplex::new(Box::new(c2s_r), Box::new(s2c_w), "memory:client".into());
    (client, server)
}

/// Dialer for a [`MemoryTransport`] (cloneable, `Send` — one per client
/// thread).
#[derive(Clone)]
pub struct MemoryConnector {
    tx: Sender<Duplex>,
}

impl MemoryConnector {
    /// Connect with default pipe bounds.
    pub fn connect(&self) -> crate::Result<Duplex> {
        self.connect_with(DEFAULT_PIPE_DEPTH, DEFAULT_WRITE_TIMEOUT)
    }

    /// Connect with explicit pipe depth / write timeout — tests shrink
    /// these to force slow-consumer shedding with small streams.
    pub fn connect_with(&self, depth: usize, write_timeout: Duration) -> crate::Result<Duplex> {
        let (client, server) = duplex_pair(depth, write_timeout);
        self.tx
            .send(server)
            .map_err(|_| err!("memory transport is no longer accepting"))?;
        Ok(client)
    }
}

/// The accept side of the in-memory transport.
pub struct MemoryTransport {
    incoming: Receiver<Duplex>,
}

impl MemoryTransport {
    pub fn new() -> (MemoryTransport, MemoryConnector) {
        let (tx, rx) = channel();
        (MemoryTransport { incoming: rx }, MemoryConnector { tx })
    }
}

impl Transport for MemoryTransport {
    fn accept(&mut self, timeout: Duration) -> crate::Result<Option<Duplex>> {
        match self.incoming.recv_timeout(timeout) {
            Ok(conn) => Ok(Some(conn)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            // Every connector dropped: keep polling as a timeout — the
            // server decides when to stop via its own flag.
            Err(RecvTimeoutError::Disconnected) => {
                std::thread::sleep(timeout);
                Ok(None)
            }
        }
    }

    fn local_addr(&self) -> String {
        "memory".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::frame::{Frame, ReadOutcome};

    #[test]
    fn frames_cross_the_pipe_both_ways() {
        let (mut transport, connector) = MemoryTransport::new();
        let mut client = connector.connect().unwrap();
        let mut server = transport
            .accept(Duration::from_millis(200))
            .unwrap()
            .expect("queued connection");

        client.send(&Frame::Subscribe { patient: 3 }).unwrap();
        match server.recv().unwrap() {
            ReadOutcome::Frame(Frame::Subscribe { patient }) => assert_eq!(patient, 3),
            _ => panic!("expected Subscribe"),
        }
        server
            .send(&Frame::Prediction {
                window: 0,
                is_ictal: false,
                margin: -4,
                model_version: 1,
            })
            .unwrap();
        match client.recv().unwrap() {
            ReadOutcome::Frame(Frame::Prediction { margin, .. }) => assert_eq!(margin, -4),
            _ => panic!("expected Prediction"),
        }
    }

    #[test]
    fn read_timeout_is_idle_and_close_is_eof() {
        let (mut transport, connector) = MemoryTransport::new();
        let client = connector.connect().unwrap();
        let mut server = transport
            .accept(Duration::from_millis(200))
            .unwrap()
            .unwrap();
        server
            .set_read_timeout(Some(Duration::from_millis(10)))
            .unwrap();
        assert!(matches!(server.recv().unwrap(), ReadOutcome::Idle));
        drop(client);
        assert!(matches!(server.recv().unwrap(), ReadOutcome::Eof));
    }

    #[test]
    fn bounded_write_to_a_stalled_reader_errors() {
        let (client, _server) = duplex_pair(1, Duration::from_millis(20));
        let mut client = client;
        // Nobody reads `_server`'s inbound pipe; the depth-1 pipe fills
        // after one write and the next must time out, not hang.
        let big = Frame::Samples {
            seq: 0,
            samples: vec![0.0; crate::params::CHANNELS],
        };
        let mut failed = false;
        for _ in 0..64 {
            if client.send(&big).is_err() {
                failed = true;
                break;
            }
        }
        assert!(failed, "writes to a stalled peer must error, not stall");
    }

    #[test]
    fn accept_times_out_without_connections() {
        let (mut transport, _connector) = MemoryTransport::new();
        let t0 = Instant::now();
        assert!(transport.accept(Duration::from_millis(30)).unwrap().is_none());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }
}
