//! Wire-level transport: how byte streams reach the coordinator.
//!
//! The serving stack is layered so the protocol logic never touches a
//! socket directly:
//!
//! * [`frame`] — the versioned binary codec (magic + length-prefixed
//!   payloads, total decode) and the [`frame::FrameDecoder`] stream
//!   reassembler;
//! * [`Transport`] — an acceptor of [`Duplex`] connections, implemented
//!   by [`memory::MemoryTransport`] (in-process pipes, deterministic
//!   tests) and [`tcp::TcpTransport`] (std `TcpListener`/`TcpStream`,
//!   dependency-free);
//! * [`client`] — the subscribe-stream-collect client used by tests and
//!   the load generator;
//! * [`loadgen`] — the replay load generator behind `repro loadgen` and
//!   its `loadgen/v1` JSON report.
//!
//! The connection actors live on the server side, in
//! [`crate::coordinator::wire`].

pub mod client;
pub mod frame;
pub mod loadgen;
pub mod memory;
pub mod tcp;

use std::io::{Read, Write};
use std::time::Duration;

use frame::{write_frame, Frame, FrameReader, ReadOutcome};

/// Readable half of a connection. A read timeout turns blocking reads
/// into [`ReadOutcome::Idle`] ticks — the actor's chance to check
/// staleness deadlines and stop flags without losing buffered bytes.
pub trait WireRead: Read + Send {
    fn set_read_timeout(&mut self, timeout: Option<Duration>) -> crate::Result<()>;
}

/// Writable half of a connection. Implementations must *bound* a write
/// to a stalled peer (write timeout / bounded pipe) — an error here is
/// how a dead consumer is detected, never an indefinite stall.
pub trait WireWrite: Write + Send {}

/// One accepted or dialed connection: framed reader + raw writer.
pub struct Duplex {
    pub reader: FrameReader<Box<dyn WireRead>>,
    pub writer: Box<dyn WireWrite>,
    /// Human-readable peer label (address or pipe name) for logs.
    pub peer: String,
}

impl Duplex {
    pub fn new(read: Box<dyn WireRead>, write: Box<dyn WireWrite>, peer: String) -> Self {
        Duplex {
            reader: FrameReader::new(read),
            writer: write,
            peer,
        }
    }

    /// Write one frame onto the wire (flushes).
    pub fn send(&mut self, frame: &Frame) -> crate::Result<()> {
        write_frame(&mut self.writer, frame)
    }

    /// Read the next frame / EOF / idle tick.
    pub fn recv(&mut self) -> crate::Result<ReadOutcome> {
        self.reader.read()
    }

    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> crate::Result<()> {
        self.reader.get_mut().set_read_timeout(timeout)
    }

    /// Split into independently-owned halves (reader actor + writer
    /// thread).
    pub fn split(self) -> (FrameReader<Box<dyn WireRead>>, Box<dyn WireWrite>, String) {
        (self.reader, self.writer, self.peer)
    }
}

/// A connection acceptor the wire server polls.
pub trait Transport: Send {
    /// Wait up to `timeout` for the next connection; `Ok(None)` on
    /// timeout (the server's chance to check its stop flag).
    fn accept(&mut self, timeout: Duration) -> crate::Result<Option<Duplex>>;

    /// The bound address clients dial (resolved, e.g. `127.0.0.1:43215`
    /// after binding port 0) or a pipe label.
    fn local_addr(&self) -> String;

    /// Bound the time a write to an accepted connection may stall on a
    /// non-draining peer. Default: transports without the notion ignore
    /// it (the in-memory pipe bounds writes at construction instead).
    fn set_write_timeout(&mut self, _timeout: Option<Duration>) {}
}
