//! Length-prefixed framed TCP, dependency-free: std `TcpListener` /
//! `TcpStream` behind the [`Transport`] trait.
//!
//! The listener runs non-blocking and is polled by [`TcpTransport::accept`]
//! so the server's accept loop can observe its stop flag; accepted
//! streams are switched back to blocking with explicit read/write
//! timeouts (reads tick as [`crate::transport::frame::ReadOutcome::Idle`],
//! bounded writes are how a non-draining peer is detected — the same
//! failure surface the in-memory pipes model).

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use super::{Duplex, Transport, WireRead, WireWrite};
use crate::err;
use crate::error::Context;

/// Poll interval while waiting for a connection on the non-blocking
/// listener.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Readable half of a TCP connection (a `try_clone` of the stream).
struct TcpRead(TcpStream);

impl Read for TcpRead {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.0.read(buf)
    }
}

impl WireRead for TcpRead {
    fn set_read_timeout(&mut self, timeout: Option<Duration>) -> crate::Result<()> {
        self.0
            .set_read_timeout(timeout)
            .map_err(|e| err!("set TCP read timeout: {e}"))
    }
}

impl WireWrite for TcpStream {}

/// Split a connected stream into a [`Duplex`] (reader clone + writer).
fn duplex_from_stream(stream: TcpStream, peer: String) -> crate::Result<Duplex> {
    stream.set_nodelay(true).ok(); // tiny frames; latency over batching
    let read_half = stream
        .try_clone()
        .map_err(|e| err!("clone TCP stream for {peer}: {e}"))?;
    Ok(Duplex::new(Box::new(TcpRead(read_half)), Box::new(stream), peer))
}

/// TCP acceptor bound to a local address.
pub struct TcpTransport {
    listener: TcpListener,
    addr: SocketAddr,
    write_timeout: Option<Duration>,
}

impl TcpTransport {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port — the
    /// resolved port is in [`Transport::local_addr`]).
    pub fn bind(addr: &str) -> crate::Result<TcpTransport> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| err!("set listener non-blocking: {e}"))?;
        let addr = listener
            .local_addr()
            .map_err(|e| err!("resolve bound address: {e}"))?;
        Ok(TcpTransport {
            listener,
            addr,
            write_timeout: None,
        })
    }

    /// Dial a server. `write_timeout` bounds how long a send may stall
    /// on a peer that stopped draining — the [`WireWrite`] contract
    /// holds for dialed streams exactly as it does for accepted ones.
    /// Long-lived connections (fleet control / proxy data paths) pass
    /// their staleness deadline; `None` leaves writes unbounded and is
    /// only appropriate for short-lived test dials.
    pub fn connect(addr: &str, write_timeout: Option<Duration>) -> crate::Result<Duplex> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        if write_timeout.is_some() {
            stream
                .set_write_timeout(write_timeout)
                .map_err(|e| err!("set write timeout on {addr}: {e}"))?;
        }
        duplex_from_stream(stream, addr.to_string())
    }
}

impl Transport for TcpTransport {
    fn accept(&mut self, timeout: Duration) -> crate::Result<Option<Duplex>> {
        let deadline = Instant::now() + timeout;
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    // Accepted sockets must block (with timeouts), even
                    // if the platform propagates the listener's
                    // non-blocking flag.
                    stream
                        .set_nonblocking(false)
                        .map_err(|e| err!("set accepted stream blocking: {e}"))?;
                    if self.write_timeout.is_some() {
                        stream
                            .set_write_timeout(self.write_timeout)
                            .map_err(|e| err!("set write timeout: {e}"))?;
                    }
                    return duplex_from_stream(stream, peer.to_string()).map(Some);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Ok(None);
                    }
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(err!("accept failed: {e}")),
            }
        }
    }

    fn local_addr(&self) -> String {
        self.addr.to_string()
    }

    fn set_write_timeout(&mut self, timeout: Option<Duration>) {
        self.write_timeout = timeout;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::frame::{Frame, ReadOutcome};

    #[test]
    fn ephemeral_bind_resolves_a_real_port() {
        let t = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = t.local_addr();
        assert!(addr.starts_with("127.0.0.1:"));
        assert_ne!(addr, "127.0.0.1:0", "port 0 must resolve");
    }

    #[test]
    fn localhost_round_trip() {
        let mut t = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = t.local_addr();
        let dialer = std::thread::spawn(move || {
            let mut c = TcpTransport::connect(&addr, Some(Duration::from_secs(5))).unwrap();
            c.send(&Frame::Subscribe { patient: 11 }).unwrap();
            match c.recv().unwrap() {
                ReadOutcome::Frame(Frame::Heartbeat { seq }) => seq,
                other => panic!(
                    "expected Heartbeat, got {:?}",
                    matches!(other, ReadOutcome::Eof)
                ),
            }
        });
        let mut server = t
            .accept(Duration::from_secs(5))
            .unwrap()
            .expect("dialer connects");
        match server.recv().unwrap() {
            ReadOutcome::Frame(Frame::Subscribe { patient }) => assert_eq!(patient, 11),
            _ => panic!("expected Subscribe"),
        }
        server.send(&Frame::Heartbeat { seq: 42 }).unwrap();
        assert_eq!(dialer.join().unwrap(), 42);
    }

    #[test]
    fn accept_timeout_returns_none() {
        let mut t = TcpTransport::bind("127.0.0.1:0").unwrap();
        let t0 = Instant::now();
        assert!(t.accept(Duration::from_millis(30)).unwrap().is_none());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }
}
