//! Batched == serial bit-exactness suite.
//!
//! The batch-first execution path (PR: multi-query AM search, batched
//! window-engine contract, coalescing job pools) must be *bit-exact*
//! with the serial paths at every batch size — 0, 1, and beyond the
//! engine host's queue depth — for both the sparse and dense kinds.
//! This file pins `search_batch` against N `search`/`search_dense`
//! calls, `run_batch` against N `run` calls, and the end-to-end host
//! path (micro-batched jobs + worker coalescing) against fresh serial
//! engine runs.

use std::sync::Arc;

use sparse_hdc_ieeg::hdc::am::{AmPlane, AssociativeMemory, Metric};
use sparse_hdc_ieeg::hdc::classifier::ClassifierConfig;
use sparse_hdc_ieeg::hdc::hv::Hv;
use sparse_hdc_ieeg::params::TEMPORAL_COUNTER_MAX;
use sparse_hdc_ieeg::runtime::engine_pool::{EngineHost, EngineSpec, Job};
use sparse_hdc_ieeg::runtime::native::{NativeWindowEngine, WINDOW_CODES};
use sparse_hdc_ieeg::runtime::EngineKind;
use sparse_hdc_ieeg::testkit::{property, Gen};

fn random_am(g: &mut Gen) -> AssociativeMemory {
    let d0 = g.f64() * 0.5;
    let d1 = g.f64() * 0.5;
    AssociativeMemory::new(g.hv(d0), g.hv(d1))
}

fn random_windows(g: &mut Gen, n: usize) -> Vec<u8> {
    let mut codes = Vec::with_capacity(n * WINDOW_CODES);
    for _ in 0..n {
        for frame in g.frames(sparse_hdc_ieeg::params::FRAMES_PER_PREDICTION) {
            codes.extend_from_slice(&frame);
        }
    }
    codes
}

// ---------------------------------------------------------------------
// hdc layer: search_batch == N searches
// ---------------------------------------------------------------------

#[test]
fn prop_search_batch_matches_serial_searches() {
    property("search_batch == N search calls, both metrics", 80, |g: &mut Gen| {
        let am = random_am(g);
        // Batch sizes including 0 and 1.
        let n = g.range(0, 40);
        let queries: Vec<Hv> = g.vec(n, |g| {
            let d = g.f64() * 0.6;
            g.hv(d)
        });
        let overlap = am.search_batch(&queries, Metric::Overlap);
        let hamming = am.search_batch(&queries, Metric::Hamming);
        assert_eq!(overlap.len(), n);
        assert_eq!(hamming.len(), n);
        for (i, q) in queries.iter().enumerate() {
            assert_eq!(overlap[i], am.search(q), "overlap query {i}");
            assert_eq!(hamming[i], am.search_dense(q), "hamming query {i}");
        }
    });
}

// ---------------------------------------------------------------------
// runtime layer: run_batch == N runs (sparse + dense engines)
// ---------------------------------------------------------------------

#[test]
fn prop_run_batch_matches_serial_runs() {
    // Batch sizes 0, 1 and up to 9 windows with mixed thresholds; both
    // engine kinds. Engines are stateless across runs (pinned in
    // runtime::native tests), so one engine serves both paths.
    property("run_batch == N run calls", 6, |g: &mut Gen| {
        let am = random_am(g);
        let plane = AmPlane::from_memory(&am);
        let n = match g.range(0, 3) {
            0 => 0,
            1 => 1,
            _ => g.range(2, 9),
        };
        let codes = random_windows(g, n);
        let thresholds: Vec<i32> = (0..n)
            .map(|_| g.range(1, TEMPORAL_COUNTER_MAX as usize) as i32)
            .collect();

        for kind in [EngineKind::SparseWindow, EngineKind::DenseWindow] {
            let cfg = if kind == EngineKind::SparseWindow {
                ClassifierConfig::optimized()
            } else {
                ClassifierConfig::default()
            };
            let mut engine = NativeWindowEngine::new(kind, cfg);
            let batch = engine.run_batch(&codes, &plane, &thresholds).unwrap();
            assert_eq!(batch.len(), n, "{kind:?}");
            for (w, &t) in thresholds.iter().enumerate() {
                let window = &codes[w * WINDOW_CODES..(w + 1) * WINDOW_CODES];
                let serial = engine.run(window, plane.i32s(), t).unwrap();
                assert_eq!(batch[w].scores, serial.scores, "{kind:?} window {w}");
                assert_eq!(batch[w].query, serial.query, "{kind:?} window {w}");
            }
        }
    });
}

// ---------------------------------------------------------------------
// pool layer: micro-batched jobs + coalescing == serial, in order
// ---------------------------------------------------------------------

#[test]
fn prop_host_with_coalescing_matches_serial_in_order() {
    // More jobs than the queue depth (blocking submits), mixed batch
    // sizes, two AM-sharing sessions interleaved so arrival-order
    // coalescing has material to work on. Every completion must carry
    // the submitted tag/seq in submission order, and every window output
    // must be byte-identical to a fresh serial run.
    const QUEUE_DEPTH: usize = 3;
    property("host jobs == serial runs, input order", 4, |g: &mut Gen| {
        let planes = [
            Arc::new(AmPlane::from_memory(&random_am(g))),
            Arc::new(AmPlane::from_memory(&random_am(g))),
        ];
        struct Sent {
            tag: u64,
            seq: u64,
            codes: Vec<u8>,
            thresholds: Vec<i32>,
            am: Arc<AmPlane>,
        }
        let jobs = g.range(QUEUE_DEPTH + 1, 2 * QUEUE_DEPTH + 4);
        let mut sent: Vec<Sent> = Vec::new();
        let mut seqs = [0u64; 2];
        for _ in 0..jobs {
            let which = g.range(0, 1);
            let windows = g.range(1, 3);
            let thresholds: Vec<i32> = (0..windows)
                .map(|_| g.range(1, TEMPORAL_COUNTER_MAX as usize) as i32)
                .collect();
            sent.push(Sent {
                tag: which as u64 + 1,
                seq: seqs[which],
                codes: random_windows(g, windows),
                thresholds,
                am: planes[which].clone(),
            });
            seqs[which] += windows as u64;
        }

        let host = EngineHost::spawn(
            EngineSpec::Native {
                cfg: ClassifierConfig::optimized(),
            },
            EngineKind::SparseWindow,
            QUEUE_DEPTH,
        )
        .unwrap();
        for s in &sent {
            host.submit(Job {
                tag: s.tag,
                seq: s.seq,
                codes: s.codes.clone(),
                am: s.am.clone(),
                thresholds: s.thresholds.clone(),
                version: 0,
                submitted: std::time::Instant::now(),
            })
            .unwrap();
        }

        let mut serial =
            NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
        for s in &sent {
            let c = host.completions.recv().unwrap();
            assert_eq!((c.tag, c.seq), (s.tag, s.seq), "submission order kept");
            let outs = c.outputs.unwrap();
            assert_eq!(outs.len(), s.thresholds.len());
            for (w, &t) in s.thresholds.iter().enumerate() {
                let window = &s.codes[w * WINDOW_CODES..(w + 1) * WINDOW_CODES];
                let expect = serial.run(window, s.am.i32s(), t).unwrap();
                assert_eq!(outs[w].scores, expect.scores, "tag {} seq {}", s.tag, s.seq);
                assert_eq!(outs[w].query, expect.query, "tag {} seq {}", s.tag, s.seq);
            }
        }
    });
}
