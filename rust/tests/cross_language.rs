//! Cross-language integration tests: the Rust golden model vs. the
//! AOT-compiled HLO artifacts executed through PJRT.
//!
//! These tests need `artifacts/` (built by `make artifacts`); they fail
//! with a clear message when it is missing.

use std::path::PathBuf;

use sparse_hdc_ieeg::hdc::am::AssociativeMemory;
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Encoder, SparseEncoder, Variant};
use sparse_hdc_ieeg::hdc::hv::Hv;
use sparse_hdc_ieeg::hdc::im::ItemMemory;
use sparse_hdc_ieeg::params::{
    CHANNELS, DIM, FRAMES_PER_PREDICTION, IM_SEED, LBP_CODES, NUM_CLASSES,
};
use sparse_hdc_ieeg::rng::Xoshiro256;
use sparse_hdc_ieeg::runtime::{Manifest, Runtime};

fn artifacts_dir() -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        dir.join("manifest.txt").exists(),
        "artifacts/ missing — run `make artifacts` first"
    );
    dir
}

#[test]
fn im_digest_matches_python() {
    let manifest = Manifest::load(&artifacts_dir()).unwrap();
    let rust_digest = ItemMemory::generate(manifest.im_seed).digest();
    assert_eq!(
        rust_digest, manifest.im_digest,
        "rust and python generated different item memories"
    );
    assert_eq!(manifest.im_seed, IM_SEED);
}

#[test]
fn sparse_engine_matches_golden_model() {
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let engine = rt.load_sparse().unwrap();

    let mut rng = Xoshiro256::new(0xC0FFEE);
    for trial in 0..3 {
        // Random window of codes + random AM + a mid-range threshold.
        let codes: Vec<u8> = (0..FRAMES_PER_PREDICTION * CHANNELS)
            .map(|_| rng.next_below(LBP_CODES as u64) as u8)
            .collect();
        let am = AssociativeMemory::new(
            Hv::random(&mut rng, 0.3),
            Hv::random(&mut rng, 0.3),
        );
        let threshold = 40 + trial * 40;

        // Golden model.
        let cfg = ClassifierConfig {
            spatial_threshold: 1,
            temporal_threshold: threshold as u16,
            ..ClassifierConfig::optimized()
        };
        let mut enc = SparseEncoder::new(Variant::Optimized, cfg);
        let mut query = None;
        let mut frame = [0u8; CHANNELS];
        for chunk in codes.chunks_exact(CHANNELS) {
            frame.copy_from_slice(chunk);
            if let Some(q) = enc.push_frame(&frame) {
                query = Some(q);
            }
        }
        let query = query.expect("one window");
        let expect_scores = [
            query.overlap(&am.classes[0]) as i32,
            query.overlap(&am.classes[1]) as i32,
        ];

        // PJRT engine.
        let out = engine.run(&codes, &am.to_i32s(), threshold as i32).unwrap();
        assert_eq!(
            out.query,
            query.to_i32s(),
            "trial {trial}: query HV mismatch (threshold {threshold})"
        );
        assert_eq!(out.scores, expect_scores, "trial {trial}: scores mismatch");
    }
}

#[test]
fn dense_engine_matches_golden_model() {
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let engine = rt.load_dense().unwrap();

    let mut rng = Xoshiro256::new(0xDECAF);
    let codes: Vec<u8> = (0..FRAMES_PER_PREDICTION * CHANNELS)
        .map(|_| rng.next_below(LBP_CODES as u64) as u8)
        .collect();
    let am = AssociativeMemory::new(Hv::random_half(&mut rng), Hv::random_half(&mut rng));

    // Golden dense model.
    let cfg = ClassifierConfig::default();
    let mut enc = sparse_hdc_ieeg::hdc::classifier::DenseEncoder::new(cfg);
    let mut query = None;
    let mut frame = [0u8; CHANNELS];
    for chunk in codes.chunks_exact(CHANNELS) {
        frame.copy_from_slice(chunk);
        if let Some(q) = enc.push_frame(&frame) {
            query = Some(q);
        }
    }
    let query = query.expect("one window");
    let expect_scores = [
        DIM as i32 - query.hamming(&am.classes[0]) as i32,
        DIM as i32 - query.hamming(&am.classes[1]) as i32,
    ];

    let out = engine.run(&codes, &am.to_i32s(), 0).unwrap();
    assert_eq!(out.query, query.to_i32s(), "dense query HV mismatch");
    assert_eq!(out.scores, expect_scores, "dense scores mismatch");
}

#[test]
fn engine_rejects_bad_shapes() {
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let engine = rt.load_sparse().unwrap();
    let am = vec![0i32; NUM_CLASSES * DIM];
    assert!(engine.run(&[0u8; 10], &am, 1).is_err());
    let codes = vec![0u8; FRAMES_PER_PREDICTION * CHANNELS];
    assert!(engine.run(&codes, &[0i32; 5], 1).is_err());
}
