//! Cross-language integration tests: the Rust golden model vs. the
//! AOT-compiled HLO artifacts executed through PJRT.
//!
//! The file compiles under the default feature set: the manifest/digest
//! contract and the native engine's conformance to the golden model are
//! always tested, and every artifact-dependent test *skips* (with a
//! message) when `artifacts/` has not been built. The PJRT engine tests
//! additionally require `--features pjrt`.

use std::path::PathBuf;

use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Encoder, SparseEncoder, Variant};
use sparse_hdc_ieeg::hdc::im::ItemMemory;
use sparse_hdc_ieeg::params::{CHANNELS, FRAMES_PER_PREDICTION, IM_SEED, LBP_CODES};
use sparse_hdc_ieeg::rng::Xoshiro256;
use sparse_hdc_ieeg::runtime::Manifest;

/// `artifacts/` next to the crate manifest, when present.
fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts/ missing — run `make artifacts`; skipping artifact-dependent test");
        None
    }
}

/// Drive one window of codes through a fresh golden-model sparse encoder.
fn golden_sparse_query(codes: &[u8], threshold: u16) -> sparse_hdc_ieeg::hdc::hv::Hv {
    let cfg = ClassifierConfig {
        spatial_threshold: 1,
        temporal_threshold: threshold,
        ..ClassifierConfig::optimized()
    };
    let mut enc = SparseEncoder::new(Variant::Optimized, cfg);
    let mut query = None;
    let mut frame = [0u8; CHANNELS];
    for chunk in codes.chunks_exact(CHANNELS) {
        frame.copy_from_slice(chunk);
        if let Some(q) = enc.push_frame(&frame) {
            query = Some(q);
        }
    }
    query.expect("one window")
}

fn random_codes(rng: &mut Xoshiro256) -> Vec<u8> {
    (0..FRAMES_PER_PREDICTION * CHANNELS)
        .map(|_| rng.next_below(LBP_CODES as u64) as u8)
        .collect()
}

#[test]
fn im_digest_matches_python() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let rust_digest = ItemMemory::generate(manifest.im_seed).digest();
    assert_eq!(
        rust_digest, manifest.im_digest,
        "rust and python generated different item memories"
    );
    assert_eq!(manifest.im_seed, IM_SEED);
}

/// The native engine implements the same window contract as the HLO
/// engines; pin it against the golden model directly (no artifacts).
#[test]
fn native_engine_matches_golden_model() {
    use sparse_hdc_ieeg::hdc::am::AssociativeMemory;
    use sparse_hdc_ieeg::hdc::hv::Hv;
    use sparse_hdc_ieeg::runtime::native::NativeWindowEngine;
    use sparse_hdc_ieeg::runtime::EngineKind;

    let mut rng = Xoshiro256::new(0xC0FFEE);
    let mut engine =
        NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
    for trial in 0..3 {
        let codes = random_codes(&mut rng);
        let am = AssociativeMemory::new(Hv::random(&mut rng, 0.3), Hv::random(&mut rng, 0.3));
        let threshold = 40 + trial * 40;

        let query = golden_sparse_query(&codes, threshold as u16);
        let expect_scores = [
            query.overlap(&am.classes[0]) as i32,
            query.overlap(&am.classes[1]) as i32,
        ];

        let out = engine.run(&codes, &am.to_i32s(), threshold).unwrap();
        assert_eq!(out.query, query.to_i32s(), "trial {trial}: query mismatch");
        assert_eq!(out.scores, expect_scores, "trial {trial}: scores mismatch");
    }
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use sparse_hdc_ieeg::hdc::am::AssociativeMemory;
    use sparse_hdc_ieeg::hdc::hv::Hv;
    use sparse_hdc_ieeg::params::{DIM, NUM_CLASSES};
    use sparse_hdc_ieeg::runtime::Runtime;

    #[test]
    fn sparse_engine_matches_golden_model() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::new(&dir).unwrap();
        let engine = rt.load_sparse().unwrap();

        let mut rng = Xoshiro256::new(0xC0FFEE);
        for trial in 0..3 {
            // Random window of codes + random AM + a mid-range threshold.
            let codes = random_codes(&mut rng);
            let am = AssociativeMemory::new(
                Hv::random(&mut rng, 0.3),
                Hv::random(&mut rng, 0.3),
            );
            let threshold = 40 + trial * 40;

            let query = golden_sparse_query(&codes, threshold as u16);
            let expect_scores = [
                query.overlap(&am.classes[0]) as i32,
                query.overlap(&am.classes[1]) as i32,
            ];

            let out = engine.run(&codes, &am.to_i32s(), threshold).unwrap();
            assert_eq!(
                out.query,
                query.to_i32s(),
                "trial {trial}: query HV mismatch (threshold {threshold})"
            );
            assert_eq!(out.scores, expect_scores, "trial {trial}: scores mismatch");
        }
    }

    #[test]
    fn dense_engine_matches_golden_model() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::new(&dir).unwrap();
        let engine = rt.load_dense().unwrap();

        let mut rng = Xoshiro256::new(0xDECAF);
        let codes = random_codes(&mut rng);
        let am = AssociativeMemory::new(Hv::random_half(&mut rng), Hv::random_half(&mut rng));

        // Golden dense model.
        let cfg = ClassifierConfig::default();
        let mut enc = sparse_hdc_ieeg::hdc::classifier::DenseEncoder::new(cfg);
        let mut query = None;
        let mut frame = [0u8; CHANNELS];
        for chunk in codes.chunks_exact(CHANNELS) {
            frame.copy_from_slice(chunk);
            if let Some(q) = enc.push_frame(&frame) {
                query = Some(q);
            }
        }
        let query = query.expect("one window");
        let expect_scores = [
            DIM as i32 - query.hamming(&am.classes[0]) as i32,
            DIM as i32 - query.hamming(&am.classes[1]) as i32,
        ];

        let out = engine.run(&codes, &am.to_i32s(), 0).unwrap();
        assert_eq!(out.query, query.to_i32s(), "dense query HV mismatch");
        assert_eq!(out.scores, expect_scores, "dense scores mismatch");
    }

    #[test]
    fn engine_rejects_bad_shapes() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::new(&dir).unwrap();
        let engine = rt.load_sparse().unwrap();
        let am = vec![0i32; NUM_CLASSES * DIM];
        assert!(engine.run(&[0u8; 10], &am, 1).is_err());
        let codes = vec![0u8; FRAMES_PER_PREDICTION * CHANNELS];
        assert!(engine.run(&codes, &[0i32; 5], 1).is_err());
    }

    /// The PJRT-parity half of the batching contract: `run_batch` must
    /// agree with the native engine's `run_batch` at batch > 1 (the PJRT
    /// side executes serially until the batched HLO artifact lands, so
    /// this pins the contract the future artifact must keep).
    #[test]
    fn batched_ab_matches_native() {
        use sparse_hdc_ieeg::hdc::am::AmPlane;
        use sparse_hdc_ieeg::runtime::native::NativeWindowEngine;
        use sparse_hdc_ieeg::runtime::EngineKind;

        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::new(&dir).unwrap();
        let engine = rt.load_sparse().unwrap();

        let mut rng = Xoshiro256::new(0xAB0);
        let thresholds = [60i32, 130, 220];
        let codes: Vec<u8> = (0..thresholds.len()).flat_map(|_| random_codes(&mut rng)).collect();
        let am = AssociativeMemory::new(Hv::random(&mut rng, 0.3), Hv::random(&mut rng, 0.3));
        let plane = AmPlane::from_memory(&am);

        let pjrt_out = engine.run_batch(&codes, plane.i32s(), &thresholds).unwrap();
        let mut native =
            NativeWindowEngine::new(EngineKind::SparseWindow, ClassifierConfig::optimized());
        let native_out = native.run_batch(&codes, &plane, &thresholds).unwrap();
        assert_eq!(pjrt_out.len(), native_out.len());
        for (w, (p, n)) in pjrt_out.iter().zip(&native_out).enumerate() {
            assert_eq!(p.scores, n.scores, "window {w}: scores mismatch");
            assert_eq!(p.query, n.query, "window {w}: query mismatch");
        }
    }
}
