//! Sharded-serving suite: the fleet dispatcher must be a *transparent*
//! control plane over the wire shards.
//!
//! The pinning contract: a client streaming a patient through the
//! dispatcher receives exactly the predictions the in-process
//! coordinator computes — window for window, label for label — no
//! matter which shard placement picks, because every shard serves the
//! same published model.
//!
//! The rebalance contract (the tentpole's acceptance bar): kill a shard
//! mid-stream and its patients re-lease to survivors; the cut session
//! ends with a reasoned "re-leased" `Shutdown`, and a replay through
//! the dispatcher resumes from the shared model state and produces the
//! full prediction stream window-for-window — zero lost windows, zero
//! duplicates in the final accounting.

use std::collections::HashMap;
use std::sync::atomic::AtomicBool;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use sparse_hdc_ieeg::config::SystemConfig;
use sparse_hdc_ieeg::coordinator::fleet::{
    effective_place, Connector, FleetConfig, FleetDispatcher,
};
use sparse_hdc_ieeg::coordinator::registry::ModelRegistry;
use sparse_hdc_ieeg::coordinator::server::{Backend, Coordinator, StreamSpec};
use sparse_hdc_ieeg::coordinator::wire::{WireConfig, WireServer};
use sparse_hdc_ieeg::data::metrics::WindowPrediction;
use sparse_hdc_ieeg::data::synth::SynthPatient;
use sparse_hdc_ieeg::err;
use sparse_hdc_ieeg::hdc::model::ModelBundle;
use sparse_hdc_ieeg::params::{CHANNELS, FRAMES_PER_PREDICTION};
use sparse_hdc_ieeg::testkit::tiny_trained_patient;
use sparse_hdc_ieeg::transport::client::{stream_record, StreamClientConfig, WirePrediction};
use sparse_hdc_ieeg::transport::frame::{write_frame, Frame, ReadOutcome};
use sparse_hdc_ieeg::transport::loadgen::{self, LoadgenConfig};
use sparse_hdc_ieeg::transport::memory::{MemoryConnector, MemoryTransport};

/// In-process ground truth for one patient's streaming record.
fn in_process_predictions(
    pid: u32,
    patient: &SynthPatient,
    bundle: &ModelBundle,
) -> Vec<WindowPrediction> {
    let report = Coordinator::new(SystemConfig::default(), Backend::Native)
        .run(vec![StreamSpec {
            session_id: 1,
            patient_id: pid,
            record: patient.records[1].clone(),
            bundle: bundle.clone(),
        }])
        .expect("in-process baseline run");
    report.sessions[0].predictions.clone()
}

/// Window-for-window equality against the in-process baseline. Because
/// the baseline has each window index exactly once, a pass here is also
/// the zero-lost / zero-duplicate check.
fn assert_pinned(
    tag: &str,
    wire: &[WirePrediction],
    baseline: &[WindowPrediction],
    version: u64,
) {
    assert_eq!(wire.len(), baseline.len(), "{tag}: prediction count");
    for (w, b) in wire.iter().zip(baseline) {
        assert_eq!(w.window as usize, b.idx, "{tag}: window order");
        assert_eq!(w.is_ictal, b.is_ictal, "{tag}: label for window {}", b.idx);
        assert_eq!(w.margin, b.margin, "{tag}: margin for window {}", b.idx);
        assert_eq!(w.model_version, version, "{tag}: model version for window {}", b.idx);
    }
}

/// Start one wire shard (slot `slot`) publishing every fixture's model —
/// the full-model-set invariant that makes re-leasing safe.
fn start_shard(
    slot: u32,
    fixtures: &[(u32, SynthPatient, ModelBundle)],
) -> (WireServer, MemoryConnector) {
    let registry = Arc::new(ModelRegistry::new());
    for (pid, _, bundle) in fixtures {
        registry.ensure(*pid, bundle.clone());
    }
    let (transport, connector) = MemoryTransport::new();
    let mut cfg = WireConfig::default();
    cfg.shard = Some(slot);
    let server = WireServer::start(
        Box::new(transport),
        &Backend::Native,
        &SystemConfig::default(),
        registry,
        cfg,
    )
    .unwrap();
    (server, connector)
}

/// Start a dispatcher over in-memory transports: shard slot K dials
/// through the connector registered under address `shard<K>`.
fn start_dispatcher(
    shard_connectors: Vec<MemoryConnector>,
    overrides: HashMap<u32, u32>,
) -> (FleetDispatcher, MemoryConnector) {
    let n = shard_connectors.len();
    let shards: Vec<String> = (0..n).map(|slot| format!("shard{slot}")).collect();
    let map: Mutex<HashMap<String, MemoryConnector>> = Mutex::new(
        shards
            .iter()
            .cloned()
            .zip(shard_connectors)
            .collect(),
    );
    let connect: Connector = Arc::new(move |addr: &str| {
        let guard = map.lock().map_err(|_| err!("connector map poisoned"))?;
        guard
            .get(addr)
            .ok_or_else(|| err!("unknown shard address {addr}"))?
            .connect()
    });
    let cfg = FleetConfig {
        shards,
        overrides,
        lease: Duration::from_secs(10),
        reap_tick: Duration::from_millis(100),
        heartbeat: Duration::from_millis(100),
        staleness: Duration::from_secs(5),
    };
    let (transport, clients) = MemoryTransport::new();
    let dispatcher = FleetDispatcher::start(Box::new(transport), connect, cfg).unwrap();
    dispatcher.wait_live(n, Duration::from_secs(10)).unwrap();
    (dispatcher, clients)
}

#[test]
fn routed_sessions_pin_to_in_process_and_announce_placement() {
    let fixtures: Vec<_> = [81u32, 82]
        .into_iter()
        .map(|pid| {
            let (patient, bundle) = tiny_trained_patient(pid);
            (pid, patient, bundle)
        })
        .collect();
    let (shard0, c0) = start_shard(0, &fixtures);
    let (shard1, c1) = start_shard(1, &fixtures);
    // Explicit placement: 81 → shard 0, 82 → shard 1.
    let overrides = HashMap::from([(81u32, 0u32), (82, 1)]);
    let (dispatcher, clients) = start_dispatcher(vec![c0, c1], overrides.clone());

    for (pid, patient, bundle) in &fixtures {
        let conn = clients.connect().unwrap();
        let outcome = stream_record(
            conn,
            *pid,
            &patient.records[1].samples,
            &StreamClientConfig::default(),
        )
        .unwrap();
        assert_eq!(
            outcome.shutdown_reason.as_deref(),
            Some("end of stream"),
            "patient {pid}"
        );
        assert!(outcome.send_error.is_none(), "patient {pid}: {:?}", outcome.send_error);
        assert_eq!(outcome.dropped(), 0, "patient {pid}");
        // The Route frame announces the placement the override table
        // dictates, with the slot's data-plane address.
        let expected = effective_place(*pid, 2, &overrides);
        assert_eq!(
            outcome.routed,
            Some((expected, format!("shard{expected}"))),
            "patient {pid}"
        );
        assert_eq!(dispatcher.leases().current(*pid), Some(expected), "patient {pid}");
        let baseline = in_process_predictions(*pid, patient, bundle);
        assert_pinned(
            &format!("patient {pid}"),
            &outcome.predictions,
            &baseline,
            bundle.version,
        );
    }

    let metrics = dispatcher.metrics();
    assert_eq!(metrics.sessions_routed.load(Relaxed), 2, "{}", metrics.summary());
    assert_eq!(metrics.routes_sent.load(Relaxed), 2, "{}", metrics.summary());
    assert_eq!(metrics.rebalances.load(Relaxed), 0, "{}", metrics.summary());
    assert_eq!(metrics.leases_granted.load(Relaxed), 2, "{}", metrics.summary());
    assert_eq!(metrics.shards_live.load(Relaxed), 2, "{}", metrics.summary());

    dispatcher.shutdown().unwrap();
    // Both shards saw a registration and an orderly data session.
    let m0 = shard0.shutdown().unwrap();
    let m1 = shard1.shutdown().unwrap();
    assert!(m0.control_hellos.load(Relaxed) >= 1, "{}", m0.summary());
    assert!(m1.control_hellos.load(Relaxed) >= 1, "{}", m1.summary());
    assert_eq!(m0.sessions_finished.load(Relaxed), 1, "{}", m0.summary());
    assert_eq!(m1.sessions_finished.load(Relaxed), 1, "{}", m1.summary());
}

#[test]
fn dead_shard_patients_re_lease_to_survivors_and_the_replay_pins() {
    let (patient, bundle) = tiny_trained_patient(91);
    let fixtures = vec![(91u32, patient, bundle)];
    let (shard0, c0) = start_shard(0, &fixtures);
    let (shard1, c1) = start_shard(1, &fixtures);
    // Pin patient 91 to shard 0 so the kill below is deterministic.
    let (dispatcher, clients) = start_dispatcher(vec![c0, c1], HashMap::from([(91u32, 0u32)]));
    let (_, patient, bundle) = &fixtures[0];
    let samples = &patient.records[1].samples;

    // Session 1: subscribe through the dispatcher and stream a 3-window
    // prefix; wait until at least one prediction proves the session is
    // flowing through shard 0.
    let conn = clients.connect().unwrap();
    let (mut reader, mut writer, _peer) = conn.split();
    reader
        .get_mut()
        .set_read_timeout(Some(Duration::from_millis(25)))
        .unwrap();
    write_frame(&mut writer, &Frame::Subscribe { patient: 91 }).unwrap();
    let prefix = &samples[..CHANNELS * FRAMES_PER_PREDICTION * 3];
    write_frame(
        &mut writer,
        &Frame::Samples {
            seq: 0,
            samples: prefix.to_vec(),
        },
    )
    .unwrap();
    let mut routed = None;
    let mut early_predictions = 0usize;
    let deadline = Instant::now() + Duration::from_secs(30);
    while early_predictions == 0 {
        assert!(Instant::now() < deadline, "no prediction through the dispatcher");
        match reader.read().unwrap() {
            ReadOutcome::Frame(Frame::Route { shard, addr, .. }) => routed = Some((shard, addr)),
            ReadOutcome::Frame(Frame::Prediction { .. }) => early_predictions += 1,
            ReadOutcome::Frame(Frame::Shutdown { reason }) => {
                panic!("session closed before the kill: {reason}")
            }
            ReadOutcome::Frame(_) | ReadOutcome::Idle => {}
            ReadOutcome::Eof => panic!("EOF before the kill"),
        }
    }
    assert_eq!(routed, Some((0, "shard0".to_string())), "pinned placement");

    // Kill shard 0 mid-session. The dispatcher's proxy sees the data
    // connection drop and closes the client with the re-lease reason.
    drop(shard0);
    let reason = loop {
        assert!(Instant::now() < deadline, "no Shutdown after the shard kill");
        match reader.read() {
            Ok(ReadOutcome::Frame(Frame::Shutdown { reason })) => break reason,
            Ok(ReadOutcome::Frame(_)) | Ok(ReadOutcome::Idle) => {}
            Ok(ReadOutcome::Eof) | Err(_) => {
                panic!("connection dropped without the reasoned re-lease Shutdown")
            }
        }
    };
    assert!(
        reason.contains("re-leased"),
        "cut session must name the re-lease: {reason}"
    );
    drop(writer);
    drop(reader);

    // Session 2: replay the whole record through the dispatcher. The
    // patient re-leases to the survivor and the replay produces the full
    // prediction stream — every window exactly once, pinned against the
    // in-process baseline (idempotent windows + the same published
    // model version on every shard).
    let conn = clients.connect().unwrap();
    let outcome =
        stream_record(conn, 91, samples, &StreamClientConfig::default()).unwrap();
    assert_eq!(outcome.shutdown_reason.as_deref(), Some("end of stream"));
    assert!(outcome.send_error.is_none(), "{:?}", outcome.send_error);
    assert_eq!(outcome.dropped(), 0);
    assert_eq!(outcome.routed, Some((1, "shard1".to_string())), "re-lease target");
    let baseline = in_process_predictions(91, patient, bundle);
    assert_pinned("replay", &outcome.predictions, &baseline, bundle.version);

    assert_eq!(dispatcher.leases().current(91), Some(1));
    let metrics = dispatcher.metrics();
    assert_eq!(metrics.rebalances.load(Relaxed), 1, "{}", metrics.summary());
    assert!(metrics.shards_dead.load(Relaxed) >= 1, "{}", metrics.summary());
    assert_eq!(metrics.sessions_routed.load(Relaxed), 2, "{}", metrics.summary());

    dispatcher.shutdown().unwrap();
    shard1.shutdown().unwrap();
}

#[test]
fn transient_data_path_failure_heals_without_losing_the_shard() {
    let (patient, bundle) = tiny_trained_patient(93);
    let fixtures = vec![(93u32, patient, bundle)];
    let (shard0, c0) = start_shard(0, &fixtures);
    let (shard1, c1) = start_shard(1, &fixtures);

    // Connector that drops exactly one dial to shard 0 on demand — a
    // transient data-path fault; the shard itself never goes away.
    let fail_next = Arc::new(AtomicBool::new(false));
    let map: Mutex<HashMap<String, MemoryConnector>> = Mutex::new(HashMap::from([
        ("shard0".to_string(), c0),
        ("shard1".to_string(), c1),
    ]));
    let fail = fail_next.clone();
    let connect: Connector = Arc::new(move |addr: &str| {
        if addr == "shard0" && fail.swap(false, Relaxed) {
            return Err(err!("injected transient dial failure"));
        }
        let guard = map.lock().map_err(|_| err!("connector map poisoned"))?;
        guard
            .get(addr)
            .ok_or_else(|| err!("unknown shard address {addr}"))?
            .connect()
    });
    let cfg = FleetConfig {
        shards: vec!["shard0".to_string(), "shard1".to_string()],
        overrides: HashMap::from([(93u32, 0u32)]),
        lease: Duration::from_secs(10),
        reap_tick: Duration::from_millis(100),
        heartbeat: Duration::from_millis(100),
        staleness: Duration::from_secs(5),
    };
    let (transport, clients) = MemoryTransport::new();
    let dispatcher = FleetDispatcher::start(Box::new(transport), connect, cfg).unwrap();
    dispatcher.wait_live(2, Duration::from_secs(10)).unwrap();
    let (_, patient, bundle) = &fixtures[0];
    let samples = &patient.records[1].samples;

    // Inject the fault and open a session: the proxy's data dial fails,
    // the client is cut with a reasoned re-lease Shutdown, and the
    // failure is *reported* — shard 0 drops out of placement.
    fail_next.store(true, Relaxed);
    let conn = clients.connect().unwrap();
    let outcome =
        stream_record(conn, 93, samples, &StreamClientConfig::default()).unwrap();
    let reason = outcome.shutdown_reason.as_deref().unwrap_or("");
    assert!(reason.contains("re-leased"), "cut session names the re-lease: {reason}");
    assert_eq!(outcome.routed, None, "no Route before the failed dial");

    // The monitor re-verifies the report with a fresh registration
    // handshake; the healthy shard is back in placement without waiting
    // out a redial backoff.
    dispatcher.wait_live(2, Duration::from_secs(10)).unwrap();
    let metrics = dispatcher.metrics();
    assert!(metrics.shards_recovered.load(Relaxed) >= 1, "{}", metrics.summary());
    assert!(metrics.shards_dead.load(Relaxed) >= 1, "{}", metrics.summary());

    // Replay: the lease still points at shard 0, which is live again —
    // the session routes straight back with no rebalance, and the
    // stream pins against the in-process baseline.
    let conn = clients.connect().unwrap();
    let outcome =
        stream_record(conn, 93, samples, &StreamClientConfig::default()).unwrap();
    assert_eq!(outcome.shutdown_reason.as_deref(), Some("end of stream"));
    assert!(outcome.send_error.is_none(), "{:?}", outcome.send_error);
    assert_eq!(outcome.dropped(), 0);
    assert_eq!(outcome.routed, Some((0, "shard0".to_string())), "healed placement");
    let baseline = in_process_predictions(93, patient, bundle);
    assert_pinned("healed replay", &outcome.predictions, &baseline, bundle.version);
    assert_eq!(dispatcher.leases().current(93), Some(0));
    assert_eq!(metrics.rebalances.load(Relaxed), 0, "{}", metrics.summary());

    dispatcher.shutdown().unwrap();
    // Shard 0 saw the original registration plus the re-verification.
    let m0 = shard0.shutdown().unwrap();
    assert!(m0.control_hellos.load(Relaxed) >= 2, "{}", m0.summary());
    shard1.shutdown().unwrap();
}

#[test]
fn loadgen_through_the_dispatcher_is_clean() {
    let fixtures: Vec<_> = [84u32, 85]
        .into_iter()
        .map(|pid| {
            let (patient, bundle) = tiny_trained_patient(pid);
            (pid, patient, bundle)
        })
        .collect();
    let (shard0, c0) = start_shard(0, &fixtures);
    let (shard1, c1) = start_shard(1, &fixtures);
    // No overrides: exercise the hash placement end to end.
    let (dispatcher, clients) = start_dispatcher(vec![c0, c1], HashMap::new());

    let records: Vec<(u32, Vec<f32>)> = fixtures
        .iter()
        .map(|(pid, patient, _)| (*pid, patient.records[1].samples.clone()))
        .collect();
    let cfg = LoadgenConfig {
        sessions: 6,
        concurrency: 3,
        ..Default::default()
    };
    let report = loadgen::run(&|| clients.connect(), &records, &cfg).unwrap();

    assert_eq!(report.failures, 0, "{}", report.summary());
    assert_eq!(report.drops, 0, "{}", report.summary());
    assert_eq!(report.sessions, 6, "{}", report.summary());
    // Every session's closing reason lands in the histogram's clean
    // bucket; the buckets account for every session.
    assert_eq!(report.shutdown_reasons.clean, 6, "{}", report.summary());
    assert_eq!(report.shutdown_reasons.total(), 6, "{}", report.summary());
    assert_eq!(report.retries, 0, "{}", report.summary());

    let metrics = dispatcher.metrics();
    assert_eq!(metrics.sessions_routed.load(Relaxed), 6, "{}", metrics.summary());
    assert_eq!(metrics.rebalances.load(Relaxed), 0, "{}", metrics.summary());
    dispatcher.shutdown().unwrap();
    shard0.shutdown().unwrap();
    shard1.shutdown().unwrap();
}
