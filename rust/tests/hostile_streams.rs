//! Hostile-stream chaos suite: the `testkit::hostile` fault injectors
//! driven end-to-end through the serving plane and the closed feedback
//! loop. Four pinned scenarios (the ISSUE's contract):
//!
//! 1. electrode dropout leaves every untouched window's prediction
//!    window-for-window identical to the clean stream;
//! 2. a planted amplitude-drift ramp fires exactly one retrain, at the
//!    window the policy replay predicts;
//! 3. retraining from the feedback ring of drifted serving windows beats
//!    retraining from the (clean) retained record on the drifted tail;
//! 4. label noise below the policy floor never triggers a retrain.
//!
//! Plus the seed contract: every injector is bit-reproducible — two
//! same-seed hostile runs produce identical prediction streams.

use std::collections::BTreeMap;
use std::sync::Arc;

use sparse_hdc_ieeg::config::SystemConfig;
use sparse_hdc_ieeg::coordinator::registry::ModelRegistry;
use sparse_hdc_ieeg::coordinator::scheduler::{PatientWatch, RetrainPolicy, RetrainScheduler};
use sparse_hdc_ieeg::coordinator::server::{Backend, Coordinator, StreamReport, StreamSpec};
use sparse_hdc_ieeg::data::metrics::window_label;
use sparse_hdc_ieeg::data::synth::Record;
use sparse_hdc_ieeg::hdc::classifier::Classifier;
use sparse_hdc_ieeg::hdc::model::ModelBundle;
use sparse_hdc_ieeg::params::{CHANNELS, FRAMES_PER_PREDICTION};
use sparse_hdc_ieeg::pipeline::{self, RetrainOptions};
use sparse_hdc_ieeg::testkit::hostile::{HostileStream, Injector};
use sparse_hdc_ieeg::testkit::tiny_trained_patient;

/// Serve one record through the in-process coordinator, optionally with
/// a retrain scheduler and a label-noise injector on the feedback path.
fn serve(
    pid: u32,
    record: Record,
    bundle: ModelBundle,
    registry: &ModelRegistry,
    scheduler: Option<Arc<RetrainScheduler>>,
    hostile_labels: Option<HostileStream>,
) -> StreamReport {
    let mut coordinator = Coordinator::new(SystemConfig::default(), Backend::Native);
    coordinator.scheduler = scheduler;
    coordinator.hostile_labels = hostile_labels;
    coordinator
        .run_with_registry(
            vec![StreamSpec {
                session_id: 1,
                patient_id: pid,
                record,
                bundle,
            }],
            registry,
            |_| {},
        )
        .unwrap()
}

/// Windows whose *input* differs between the clean and corrupted stream,
/// with an LBP-memory halo: a corrupted sample at frame `t` can perturb
/// the per-channel 6-bit code for the next `LBP_BITS` frames (the code
/// is a shift register of difference signs, and the first comparison
/// after the span uses the corrupted `last` sample), so frames
/// `t..=t+LBP_BITS+1` — and every window containing one — count as
/// affected. Everything outside this set must predict identically.
fn affected_windows(clean: &Record, corrupt: &Record) -> Vec<bool> {
    const HALO_FRAMES: usize = 8; // LBP_BITS (6) + the held `last` + slack
    let frames = clean.num_samples();
    let windows = frames / FRAMES_PER_PREDICTION;
    let mut affected = vec![false; windows];
    for t in 0..frames {
        if clean.samples[t * CHANNELS..(t + 1) * CHANNELS]
            != corrupt.samples[t * CHANNELS..(t + 1) * CHANNELS]
        {
            for h in t..(t + HALO_FRAMES + 1).min(frames) {
                let w = h / FRAMES_PER_PREDICTION;
                if w < windows {
                    affected[w] = true;
                }
            }
        }
    }
    affected
}

/// Scenario 1: per-channel dropout spans perturb only the windows they
/// (plus the LBP halo) actually touch — every other window's prediction
/// is bit-identical to the clean stream's.
#[test]
fn dropout_leaves_untouched_windows_identical() {
    let (patient, bundle) = tiny_trained_patient(21);
    let clean = patient.records[1].clone();
    // One 64-frame span per hit channel; at 0.15 (~10 of 64 channels)
    // the spans cannot blanket all 28 windows, so the "untouched windows
    // exist" premise holds for any seed.
    let hostile = HostileStream::new(0xD209).with(Injector::Dropout {
        rate: 0.15,
        span_frames: 64,
        stuck: false,
    });
    let mut corrupt = clean.clone();
    hostile.corrupt(&mut corrupt.samples);
    assert_ne!(
        clean.samples, corrupt.samples,
        "seeded dropout must lift at least one lead"
    );

    let affected = affected_windows(&clean, &corrupt);
    assert!(
        affected.iter().any(|a| !*a),
        "seeded spans must leave some windows untouched — lower the rate"
    );
    assert!(affected.iter().any(|a| *a));

    let registry = ModelRegistry::new();
    let a = serve(21, clean, bundle.clone(), &registry, None, None);
    let registry = ModelRegistry::new();
    let b = serve(21, corrupt, bundle, &registry, None, None);
    assert_eq!(a.sessions[0].predictions.len(), b.sessions[0].predictions.len());
    for (w, touched) in affected.iter().enumerate() {
        if !*touched {
            assert_eq!(
                a.sessions[0].predictions[w], b.sessions[0].predictions[w],
                "window {w} is outside every dropout span but predicted differently"
            );
        }
    }
}

/// Scenario 2: a drift ramp over the served stream fires exactly one
/// retrain, at the window a pure policy replay of the outcome stream
/// predicts. The trigger index is a deterministic function of the
/// (prediction, ground-truth) stream — no clocks, no thread timing — so
/// the scheduler-less baseline run tells us the window in advance.
#[test]
fn planted_drift_ramp_fires_exactly_one_retrain_at_the_predicted_window() {
    let (patient, bundle) = tiny_trained_patient(22);
    let mut drifted = patient.records[1].clone();
    HostileStream::new(0xD21F)
        .with(Injector::Drift {
            start_frame: 0,
            gain: 6.0,
        })
        .corrupt(&mut drifted.samples);
    assert_ne!(drifted.samples, patient.records[1].samples);

    let policy = RetrainPolicy {
        epochs: 2,
        fa_window: 4,
        fa_rate: 0.0,
        cooldown: 10_000,
        max_retrains: 1,
    };

    // Baseline: serve without a scheduler, then replay the outcome
    // stream through a fresh PatientWatch to predict the trigger window.
    let registry = ModelRegistry::new();
    let baseline = serve(22, drifted.clone(), bundle.clone(), &registry, None, None);
    let mut watch = PatientWatch::new(&policy);
    let mut predicted = None;
    for p in &baseline.sessions[0].predictions {
        let truth = window_label(&drifted, p.idx);
        if watch.observe(&policy, p.is_ictal && !truth) {
            predicted = Some(watch.windows_seen);
            break;
        }
    }
    let predicted = predicted.expect("a zero-rate policy fires once the estimator fills");

    // Real run: same stream, foreground scheduler, record retained.
    let registry = Arc::new(ModelRegistry::new());
    let mut train = BTreeMap::new();
    train.insert(22, patient.records[0].clone());
    let scheduler = Arc::new(
        RetrainScheduler::new(policy, registry.clone(), None, train).foreground(),
    );
    let report = serve(
        22,
        drifted,
        bundle,
        &registry,
        Some(scheduler.clone()),
        None,
    );

    assert_eq!(
        scheduler.triggers(),
        vec![(22, predicted)],
        "exactly one retrain, at the replay-predicted window"
    );
    assert_eq!(scheduler.retrains(22), 1);
    assert_eq!(scheduler.published_retrains(22), 1, "the trigger's retrain published");
    assert_eq!(report.metrics.retrains_triggered, 1);
    assert_eq!(registry.current(22).unwrap().version(), 2);
    let msgs = scheduler.join();
    assert_eq!(msgs.len(), 1);
    assert!(msgs[0].contains("published model v2"), "{}", msgs[0]);
}

/// Scenario 3: on the drifted tail of a stream, a retrain from the
/// feedback ring (labelled *drifted* serving windows) classifies at
/// least as well as a retrain from the retained — clean — training
/// record. This is the point of closing the loop: the ring is what the
/// stream looks like *now*.
#[test]
fn feedback_retrain_beats_record_retrain_on_the_drifted_tail() {
    let (patient, bundle) = tiny_trained_patient(23);
    let mut drifted = patient.records[1].clone();
    // The LBP front-end codes difference *signs*, so a gentle gain ramp
    // is nearly invisible to it; a steep tail ramp plus frozen-ADC
    // spans (stuck leads emit constant codes) gives the tail a code
    // distribution the clean training record genuinely does not have.
    HostileStream::new(0xFEED)
        .with(Injector::Drift {
            start_frame: 4096,
            gain: 16.0,
        })
        .with(Injector::Dropout {
            rate: 1.0,
            span_frames: 2048,
            stuck: true,
        })
        .corrupt(&mut drifted.samples);
    let tail_start = (drifted.num_samples() - 8 * FRAMES_PER_PREDICTION) * CHANNELS;
    assert_ne!(
        &drifted.samples[tail_start..],
        &patient.records[1].samples[tail_start..],
        "the tail itself must be corrupted for the comparison to be about drift"
    );

    // Assemble the drifted stream's windows exactly as a serving session
    // does: streaming LBP codes, frame-major, majority-vote labels.
    let mut windows: Vec<(Vec<u8>, bool)> = Vec::new();
    let mut codes = Vec::with_capacity(FRAMES_PER_PREDICTION * CHANNELS);
    let mut ictal_frames = 0usize;
    for (frame, ictal) in pipeline::record_frames(&drifted) {
        codes.extend_from_slice(&frame);
        ictal_frames += ictal as usize;
        if codes.len() == FRAMES_PER_PREDICTION * CHANNELS {
            windows.push((std::mem::take(&mut codes), ictal_frames * 2 > FRAMES_PER_PREDICTION));
            ictal_frames = 0;
        }
    }
    let ring = 8usize;
    assert!(windows.len() > ring, "stream long enough to have a tail");
    let tail: Vec<(Vec<u8>, bool)> = windows[windows.len() - ring..].to_vec();
    assert!(
        tail.iter().any(|(_, l)| *l) && tail.iter().any(|(_, l)| !*l),
        "the tail must carry both classes for the comparison to mean anything"
    );

    let opts = RetrainOptions {
        max_epochs: 4,
        ..Default::default()
    };
    let (from_feedback, fb_report) =
        pipeline::retrain_bundle_from_windows(&bundle, &tail, &opts);
    let (from_record, _) = pipeline::retrain_bundle(&bundle, &patient.records[0], &opts);
    assert!(fb_report.best_errors <= fb_report.initial_errors);

    // Score both retrained models over the drifted stream; count
    // misclassifications on the tail windows only.
    let tail_errors = |b: &ModelBundle| -> usize {
        let mut clf = Classifier::new(b.variant, b.config.clone(), b.am.clone());
        let preds = pipeline::run_on_record(&mut clf, &drifted);
        assert_eq!(preds.len(), windows.len());
        preds[preds.len() - ring..]
            .iter()
            .zip(&tail)
            .filter(|(p, (_, truth))| p.is_ictal != *truth)
            .count()
    };
    let fb_errors = tail_errors(&from_feedback);
    let rec_errors = tail_errors(&from_record);
    assert!(
        fb_errors <= rec_errors,
        "feedback retrain mispredicts {fb_errors}/{ring} drifted-tail windows, \
         record retrain {rec_errors}/{ring} — the ring should win on its own distribution"
    );
}

/// Scenario 4: label noise on the feedback path at a rate well below the
/// policy's false-alarm floor never fires a retrain — the estimator's
/// sliding window absorbs sub-threshold flip rates.
#[test]
fn label_noise_below_the_policy_floor_never_triggers() {
    let (patient, bundle) = tiny_trained_patient(24);
    let hostile = HostileStream::new(0x1AB1).with(Injector::LabelNoise { p: 0.2 });
    // Injector sanity at this seed: the per-window coin does flip.
    assert!(
        (0..1000u64).any(|w| hostile.corrupt_label(w, false)),
        "seeded label noise never flipped — injector broken or seed degenerate"
    );

    let policy = RetrainPolicy {
        epochs: 2,
        fa_window: 16,
        fa_rate: 0.75, // the floor: 12 of 16 windows must be false alarms
        cooldown: 10_000,
        max_retrains: 0,
    };
    let registry = Arc::new(ModelRegistry::new());
    let mut train = BTreeMap::new();
    train.insert(24, patient.records[0].clone());
    let scheduler = Arc::new(
        RetrainScheduler::new(policy, registry.clone(), None, train).foreground(),
    );
    let report = serve(
        24,
        patient.records[1].clone(),
        bundle,
        &registry,
        Some(scheduler.clone()),
        Some(hostile),
    );

    assert!(report.sessions[0].windows > 16, "estimator window filled at least once");
    assert!(
        scheduler.triggers().is_empty(),
        "sub-floor label noise must not trigger: {:?}",
        scheduler.triggers()
    );
    assert_eq!(scheduler.retrains(24), 0);
    assert_eq!(registry.current(24).unwrap().version(), 1, "nothing published");
    assert!(scheduler.join().is_empty());
}

/// The seed contract: a hostile spec parsed from the CLI vocabulary is
/// bit-reproducible — two same-seed corruptions are identical sample
/// streams, two same-seed serving runs are identical prediction
/// streams, and a different seed actually produces a different stream.
#[test]
fn hostile_runs_are_bit_reproducible_from_the_seed() {
    let (patient, bundle) = tiny_trained_patient(25);
    let corrupt_with = |seed: u64| -> Record {
        let hostile = HostileStream::parse("dropout,drift,jitter", seed).unwrap();
        let mut record = patient.records[1].clone();
        hostile.corrupt(&mut record.samples);
        record
    };

    let a = corrupt_with(0xC0FFEE);
    let b = corrupt_with(0xC0FFEE);
    assert_eq!(a.samples, b.samples, "same seed, same corruption, bit for bit");
    assert_ne!(
        corrupt_with(0xC0FFEF).samples,
        a.samples,
        "a different seed must corrupt differently"
    );

    let run = |record: Record| {
        let registry = ModelRegistry::new();
        serve(25, record, bundle.clone(), &registry, None, None).sessions[0]
            .predictions
            .clone()
    };
    assert_eq!(
        run(a),
        run(b),
        "same-seed hostile runs must produce identical prediction streams"
    );
}
