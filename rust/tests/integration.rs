//! Cross-module integration tests: dataset round-trips through the full
//! one-shot workflow, native-vs-PJRT serving equality, config layering,
//! and failure injection (corrupt artifacts, corrupt datasets, bad
//! sessions, tiny queues).

use std::path::PathBuf;

use sparse_hdc_ieeg::config::{ConfigFile, SystemConfig};
use sparse_hdc_ieeg::coordinator::server::{Backend, Coordinator, StreamSpec};
use sparse_hdc_ieeg::data::dataset;
use sparse_hdc_ieeg::data::metrics::AlarmPolicy;
use sparse_hdc_ieeg::data::synth::{PatientProfile, SynthConfig, SynthPatient};
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, SparseEncoder, Variant};
use sparse_hdc_ieeg::pipeline;
use sparse_hdc_ieeg::runtime::engine_pool::{EngineHost, EngineSpec, Job};
use sparse_hdc_ieeg::runtime::EngineKind;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("hdc_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn tiny_synth() -> SynthConfig {
    SynthConfig {
        records_per_patient: 2,
        pre_s: 4.0,
        ictal_s: 3.0,
        post_s: 1.0,
        ..Default::default()
    }
}

#[test]
fn one_shot_workflow_through_disk() {
    // gen-data → save → load → train → detect, entirely via public API.
    let dir = tmpdir("workflow");
    let cfg = tiny_synth();
    let patient = SynthPatient::generate(&cfg, 5);
    dataset::save_patient(&patient.records, &dir, 5).unwrap();

    let records = dataset::load_patient(&dir, 5).unwrap();
    assert_eq!(records.len(), 2);
    let loaded = SynthPatient {
        profile: PatientProfile::derive(&cfg, 5),
        records,
    };
    let eval = pipeline::evaluate_patient(
        Variant::Optimized,
        &ClassifierConfig::optimized(),
        &loaded,
        Some(0.25),
        AlarmPolicy::default(),
    );
    assert_eq!(eval.summary.seizures, 1);
    // Must match the in-memory evaluation exactly (float round-trip safe:
    // the format stores f32 verbatim).
    let eval_mem = pipeline::evaluate_patient(
        Variant::Optimized,
        &ClassifierConfig::optimized(),
        &patient,
        Some(0.25),
        AlarmPolicy::default(),
    );
    assert_eq!(eval.summary.detected, eval_mem.summary.detected);
    assert_eq!(eval.temporal_threshold, eval_mem.temporal_threshold);
    std::fs::remove_dir_all(&dir).ok();
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_and_native_serving_agree() {
    // The same streams through both backends must yield identical
    // per-window predictions (cross_language.rs proves single windows;
    // this proves the full serving path incl. session state).
    if !PathBuf::from("artifacts/manifest.txt").exists() {
        eprintln!("artifacts/ missing — run `make artifacts`; skipping pjrt serving test");
        return;
    }
    let cfg = ClassifierConfig::optimized();
    let patient = SynthPatient::generate(&tiny_synth(), 9);
    let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
    let bundle = pipeline::train_on_record(&mut enc, patient.train_record(), &cfg);
    let spec = |sid| StreamSpec {
        session_id: sid,
        patient_id: 9,
        record: patient.records[1].clone(),
        bundle: bundle.clone(),
    };

    let native = Coordinator::new(SystemConfig::default(), Backend::Native)
        .run(vec![spec(1)])
        .unwrap();
    let pjrt = Coordinator::new(
        SystemConfig::default(),
        Backend::Pjrt {
            artifacts_dir: "artifacts".into(),
        },
    )
    .run(vec![spec(1)])
    .unwrap();

    assert_eq!(native.sessions[0].windows, pjrt.sessions[0].windows);
    assert_eq!(native.sessions[0].eval.detected, pjrt.sessions[0].eval.detected);
    assert_eq!(native.sessions[0].eval.delay_s, pjrt.sessions[0].eval.delay_s);
    assert_eq!(
        native.sessions[0].alarms.len(),
        pjrt.sessions[0].alarms.len()
    );
}

#[test]
fn backpressure_with_depth_one_queue_completes() {
    let mut system = SystemConfig::default();
    system.queue_depth = 1;
    let cfg = ClassifierConfig::optimized();
    let patient = SynthPatient::generate(&tiny_synth(), 3);
    let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
    let bundle = pipeline::train_on_record(&mut enc, patient.train_record(), &cfg);
    let report = Coordinator::new(system, Backend::Native)
        .run(vec![StreamSpec {
            session_id: 1,
            patient_id: 3,
            record: patient.records[1].clone(),
            bundle,
        }])
        .unwrap();
    assert_eq!(report.metrics.windows_failed, 0);
    assert!(report.metrics.windows_completed > 0);
}

#[cfg(feature = "pjrt")]
#[test]
fn corrupt_artifact_fails_cleanly() {
    let dir = tmpdir("corrupt");
    std::fs::write(
        dir.join("manifest.txt"),
        "frames = 256\nchannels = 64\ndim = 1024\nnum_classes = 2\n\
         im_seed = 0x5eed1ee600000001\nim_digest = 0xf7cdf969f2b33a13\n\
         sparse_window = sparse_window.hlo.txt\ndense_window = dense_window.hlo.txt\n",
    )
    .unwrap();
    std::fs::write(dir.join("sparse_window.hlo.txt"), "this is not HLO").unwrap();
    let err = EngineHost::spawn(
        EngineSpec::Pjrt {
            artifacts_dir: dir.clone(),
        },
        EngineKind::SparseWindow,
        2,
    );
    assert!(err.is_err(), "corrupt HLO must fail at spawn, not at runtime");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn native_engine_host_serves_and_reports_job_errors() {
    // The default build's engine host: construction succeeds without any
    // artifacts, malformed jobs come back as error completions (not
    // thread panics), and well-formed jobs complete after them.
    use sparse_hdc_ieeg::hdc::am::{AmPlane, AssociativeMemory};
    use sparse_hdc_ieeg::hdc::hv::Hv;
    use sparse_hdc_ieeg::params::{CHANNELS, DIM, FRAMES_PER_PREDICTION};
    use std::sync::Arc;

    let host = EngineHost::spawn(
        EngineSpec::Native {
            cfg: ClassifierConfig::optimized(),
        },
        EngineKind::SparseWindow,
        4,
    )
    .expect("native engine needs no artifacts");
    let am = Arc::new(AmPlane::from_memory(&AssociativeMemory::new(Hv::zero(), Hv::zero())));
    let job = |seq: u64, codes: Vec<u8>| Job::single(9, seq, codes, am.clone(), 130);
    host.submit(job(0, vec![0u8; 3 * CHANNELS])).unwrap(); // truncated window
    host.submit(job(1, vec![0u8; FRAMES_PER_PREDICTION * CHANNELS]))
        .unwrap();
    let bad = host.completions.recv().unwrap();
    assert_eq!(bad.seq, 0);
    assert!(bad.outputs.is_err());
    let good = host.completions.recv().unwrap();
    assert_eq!(good.seq, 1);
    let outs = good.outputs.unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].query.len(), DIM);
}

#[test]
fn corrupt_dataset_fails_cleanly() {
    let dir = tmpdir("badds");
    let pdir = dir.join("patient_07");
    std::fs::create_dir_all(&pdir).unwrap();
    std::fs::write(pdir.join("record_00.ieeg"), vec![0u8; 100]).unwrap();
    assert!(dataset::load_patient(&dir, 7).is_err());
    // Truncated payload: valid header, short samples.
    let cfg = tiny_synth();
    let p = SynthPatient::generate(&cfg, 7);
    let path = pdir.join("record_01.ieeg");
    dataset::save_record(&p.records[0], &path).unwrap();
    let full = std::fs::read(&path).unwrap();
    std::fs::write(&path, &full[..full.len() / 2]).unwrap();
    assert!(dataset::load_record(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn config_drives_coordinator_behaviour() {
    let file = ConfigFile::parse(
        "[system]\nvariant = \"sparse-optimized\"\n\
         [classifier]\ntemporal_threshold = 90\n\
         [detector]\nconsecutive = 3\n\
         [coordinator]\nqueue_depth = 2\n",
    )
    .unwrap();
    let system = SystemConfig::from_file(&file).unwrap();
    assert_eq!(system.classifier.temporal_threshold, 90);
    assert_eq!(system.alarm_consecutive, 3);

    // consecutive=3 suppresses short runs end-to-end.
    let cfg = ClassifierConfig {
        temporal_threshold: 90,
        ..ClassifierConfig::optimized()
    };
    let patient = SynthPatient::generate(&tiny_synth(), 4);
    let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
    let bundle = pipeline::train_on_record(&mut enc, patient.train_record(), &cfg);
    assert_eq!(bundle.config.temporal_threshold, 90, "bundle carries the tuned threshold");
    let report = Coordinator::new(system, Backend::Native)
        .run(vec![StreamSpec {
            session_id: 1,
            patient_id: 4,
            record: patient.records[1].clone(),
            bundle,
        }])
        .unwrap();
    // All alarms obey the 3-consecutive policy: the detector fired at most
    // once per ictal run and never in the first two windows.
    for alarm in &report.sessions[0].alarms {
        assert!(alarm.window_idx >= 2);
    }
}

#[test]
fn multi_patient_interleaving_isolated() {
    // Sessions must not leak state into each other: serving P1+P2 together
    // must give each the same result as serving it alone.
    let cfg = ClassifierConfig::optimized();
    let mk = |pid: u32| {
        let p = SynthPatient::generate(&tiny_synth(), pid);
        let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
        let bundle = pipeline::train_on_record(&mut enc, p.train_record(), &cfg);
        StreamSpec {
            session_id: pid as u64,
            patient_id: pid,
            record: p.records[1].clone(),
            bundle,
        }
    };
    let solo1 = Coordinator::new(SystemConfig::default(), Backend::Native)
        .run(vec![mk(1)])
        .unwrap();
    let solo2 = Coordinator::new(SystemConfig::default(), Backend::Native)
        .run(vec![mk(2)])
        .unwrap();
    let both = Coordinator::new(SystemConfig::default(), Backend::Native)
        .run(vec![mk(1), mk(2)])
        .unwrap();
    let find = |r: &sparse_hdc_ieeg::coordinator::server::StreamReport, id: u64| {
        r.sessions
            .iter()
            .find(|s| s.session_id == id)
            .map(|s| (s.windows, s.eval.detected, s.eval.delay_s))
            .unwrap()
    };
    assert_eq!(find(&both, 1), find(&solo1, 1));
    assert_eq!(find(&both, 2), find(&solo2, 2));
}
