//! Kernel-equivalence and evaluation-pool determinism suite.
//!
//! The word-parallel hot path (bit-sliced spatial/temporal counters,
//! branchless comparators, word-mask OR) must be *bit-exact* against the
//! retained scalar `*_reference` implementations for every input — this
//! file pins that across random inputs and all thresholds. It also pins
//! that the sharded [`evalpool`] produces exactly the serial path's
//! results in exactly the serial path's order.

use sparse_hdc_ieeg::data::metrics::AlarmPolicy;
use sparse_hdc_ieeg::data::synth::{SynthConfig, SynthPatient};
use sparse_hdc_ieeg::evalpool;
use sparse_hdc_ieeg::hdc::bundling::{self, SpatialCounts, SPATIAL_PLANES};
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Variant};
use sparse_hdc_ieeg::hdc::hv::Hv;
use sparse_hdc_ieeg::hdc::sparse::SparseHv;
use sparse_hdc_ieeg::hdc::temporal::{TemporalAccumulator, TemporalAccumulatorReference};
use sparse_hdc_ieeg::params::{CHANNELS, TEMPORAL_COUNTER_MAX};
use sparse_hdc_ieeg::pipeline::{self, PatientEval};
use sparse_hdc_ieeg::testkit::{property, Gen};

// ---------------------------------------------------------------------
// Spatial bundling: word-parallel vs scalar reference
// ---------------------------------------------------------------------

#[test]
fn prop_or_tree_matches_reference() {
    property("bundle_or_pos == scalar reference", 200, |g: &mut Gen| {
        let n = g.range(0, CHANNELS);
        let hvs: Vec<SparseHv> = g.vec(n, |g| g.sparse_hv());
        assert_eq!(bundling::bundle_or_pos(&hvs), bundling::bundle_or_pos_reference(&hvs));
    });
}

#[test]
fn prop_element_counts_match_reference() {
    property("bit-sliced counts (bit/pos) == scalar scatter", 100, |g| {
        let n = g.range(0, CHANNELS);
        let pos: Vec<SparseHv> = g.vec(n, |g| g.sparse_hv());
        let bits: Vec<Hv> = pos.iter().map(|p| p.to_hv()).collect();
        let mut from_bits = SpatialCounts::new();
        let mut from_pos = SpatialCounts::new();
        for (p, h) in pos.iter().zip(bits.iter()) {
            from_pos.add_sparse(p);
            from_bits.add_hv(h);
        }
        assert_eq!(*from_bits.counts(), *bundling::element_counts_reference(&bits));
        assert_eq!(*from_pos.counts(), *bundling::element_counts_pos_reference(&pos));
    });
}

#[test]
fn prop_thin_matches_reference_all_thresholds() {
    property("thin / bit-sliced thin == reference, every threshold", 60, |g| {
        let n = g.range(1, CHANNELS);
        let pos: Vec<SparseHv> = g.vec(n, |g| g.sparse_hv());
        let bits: Vec<Hv> = pos.iter().map(|p| p.to_hv()).collect();
        let counts = bundling::element_counts_reference(&bits);
        let mut acc = SpatialCounts::new();
        for p in &pos {
            acc.add_sparse(p);
        }
        // All reachable thresholds plus the out-of-range tail.
        for t in 0..=(1 << SPATIAL_PLANES) {
            let expect = bundling::thin_reference(&counts, t);
            assert_eq!(bundling::thin(&counts, t), expect, "thin t={t}");
            assert_eq!(acc.thin(t), expect, "bit-sliced thin t={t}");
            assert_eq!(bundling::bundle_adder_thin(&bits, t), expect, "bundle_adder_thin t={t}");
            assert_eq!(bundling::bundle_adder_thin_pos(&pos, t), expect, "adder_thin_pos t={t}");
        }
    });
}

#[test]
fn prop_element_counts_accept_dense_inputs() {
    // The adder tree also bundles arbitrary-density HVs (the baseline
    // variant feeds it bound bit-domain HVs); the bit-sliced counters
    // must match for those too.
    property("dense-input adder tree == reference", 60, |g| {
        let n = g.range(1, 32);
        let hvs: Vec<Hv> = g.vec(n, |g| {
            let d = g.f64();
            g.hv(d)
        });
        let counts = bundling::element_counts_reference(&hvs);
        let mut acc = SpatialCounts::new();
        for hv in &hvs {
            acc.add_hv(hv);
        }
        assert_eq!(*acc.counts(), *counts);
        for t in [0u16, 1, 2, n as u16 / 2 + 1, n as u16, n as u16 + 1] {
            assert_eq!(
                bundling::bundle_adder_thin(&hvs, t),
                bundling::thin_reference(&counts, t),
                "t={t}"
            );
        }
    });
}

// ---------------------------------------------------------------------
// Temporal accumulator: bit-sliced vs scalar reference
// ---------------------------------------------------------------------

#[test]
fn prop_temporal_accumulator_matches_reference() {
    property("bit-sliced temporal == reference (incl. saturation)", 40, |g| {
        let mut fast = TemporalAccumulator::new();
        let mut slow = TemporalAccumulatorReference::new();
        // Past-saturation streams: up to 300 frames of varied density.
        let frames = g.range(1, 300);
        for _ in 0..frames {
            let d = g.f64() * 0.8;
            let f = g.hv(d);
            fast.add(&f);
            slow.add(&f);
        }
        assert_eq!(fast.frames(), slow.frames());
        assert_eq!(*fast.counts(), *slow.counts());
        for t in 0..=(TEMPORAL_COUNTER_MAX + 2) {
            assert_eq!(fast.peek(t), slow.peek(t), "threshold {t}");
        }
        let t = g.range(1, TEMPORAL_COUNTER_MAX as usize) as u16;
        assert_eq!(fast.finish(t), slow.finish(t));
        assert_eq!(*fast.counts(), *slow.counts());
        assert_eq!(fast.frames(), 0);
    });
}

#[test]
fn temporal_saturation_pins_at_counter_max() {
    let mut fast = TemporalAccumulator::new();
    let mut slow = TemporalAccumulatorReference::new();
    let f = Hv::ones();
    for _ in 0..(TEMPORAL_COUNTER_MAX as usize + 50) {
        fast.add(&f);
        slow.add(&f);
    }
    assert_eq!(*fast.counts(), *slow.counts());
    assert!(fast.counts().iter().all(|&c| c == TEMPORAL_COUNTER_MAX));
    assert_eq!(fast.peek(TEMPORAL_COUNTER_MAX), Hv::ones());
    assert_eq!(fast.peek(TEMPORAL_COUNTER_MAX + 1), Hv::zero());
}

// ---------------------------------------------------------------------
// Evaluation pool: parallel output == serial output, same order
// ---------------------------------------------------------------------

fn synthetic_cohort(n: usize) -> Vec<SynthPatient> {
    let synth = SynthConfig {
        records_per_patient: 2,
        pre_s: 6.0,
        ictal_s: 4.0,
        post_s: 2.0,
        ..Default::default()
    };
    (1..=n as u32)
        .map(|pid| SynthPatient::generate(&synth, pid))
        .collect()
}

fn assert_evals_equal(parallel: &[PatientEval], serial: &[PatientEval]) {
    assert_eq!(parallel.len(), serial.len());
    for (p, s) in parallel.iter().zip(serial.iter()) {
        assert_eq!(p.patient_id, s.patient_id, "result order must be input order");
        assert_eq!(p.temporal_threshold, s.temporal_threshold);
        assert_eq!(p.summary.detected, s.summary.detected);
        assert_eq!(p.summary.seizures, s.summary.seizures);
        assert_eq!(p.summary.false_alarms, s.summary.false_alarms);
        assert_eq!(p.summary.mean_delay_s().to_bits(), s.summary.mean_delay_s().to_bits());
        assert_eq!(
            p.mean_query_density.to_bits(),
            s.mean_query_density.to_bits(),
            "bit-exact density"
        );
    }
}

#[test]
fn evalpool_matches_serial_evaluation() {
    let patients = synthetic_cohort(3);
    let policy = AlarmPolicy { consecutive: 1 };
    // The full (variant × max-density × patient) job shape the sweep
    // commands shard.
    let jobs: Vec<(Variant, Option<f64>, usize)> = [
        (Variant::Optimized, Some(0.15)),
        (Variant::Optimized, Some(0.30)),
        (Variant::SparseCompIm, Some(0.30)),
        (Variant::DenseBaseline, None),
    ]
    .iter()
    .flat_map(|&(v, d)| (0..patients.len()).map(move |i| (v, d, i)))
    .collect();

    let eval = |&(variant, max_d, i): &(Variant, Option<f64>, usize)| {
        let cfg = if variant == Variant::Optimized {
            ClassifierConfig::optimized()
        } else {
            ClassifierConfig::default()
        };
        pipeline::evaluate_patient(variant, &cfg, &patients[i], max_d, policy)
    };

    let serial = evalpool::map_with(1, &jobs, eval);
    let parallel = evalpool::map_with(4, &jobs, eval);
    assert_evals_equal(&parallel, &serial);
}

#[test]
fn evalpool_ordering_is_input_order_under_skew() {
    // Jobs finishing out of order (patient sizes differ) must not reorder
    // results.
    let patients = synthetic_cohort(5);
    let jobs: Vec<usize> = (0..patients.len()).rev().collect();
    let ids = evalpool::map_with(3, &jobs, |&i| patients[i].profile.id);
    let expect: Vec<u32> = jobs.iter().map(|&i| patients[i].profile.id).collect();
    assert_eq!(ids, expect);
}
