//! Kernel-equivalence and evaluation-pool determinism suite.
//!
//! The word-parallel hot path (bit-sliced spatial/temporal counters,
//! branchless comparators, word-mask OR) must be *bit-exact* against the
//! retained scalar `*_reference` implementations for every input — this
//! file pins that across random inputs and all thresholds. It also pins
//! that the sharded [`evalpool`] produces exactly the serial path's
//! results in exactly the serial path's order.

use sparse_hdc_ieeg::data::metrics::AlarmPolicy;
use sparse_hdc_ieeg::data::synth::{SynthConfig, SynthPatient};
use sparse_hdc_ieeg::evalpool;
use sparse_hdc_ieeg::hdc::am::{AssociativeMemory, Metric};
use sparse_hdc_ieeg::hdc::bundling::{self, SpatialCounts, SPATIAL_PLANES};
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Variant};
use sparse_hdc_ieeg::hdc::hv::{Hv, WORDS};
use sparse_hdc_ieeg::hdc::simd::{self, KernelSet};
use sparse_hdc_ieeg::hdc::sparse::SparseHv;
use sparse_hdc_ieeg::hdc::temporal::{
    TemporalAccumulator, TemporalAccumulatorReference, TEMPORAL_PLANES,
};
use sparse_hdc_ieeg::params::{CHANNELS, TEMPORAL_COUNTER_MAX};
use sparse_hdc_ieeg::pipeline::{self, PatientEval};
use sparse_hdc_ieeg::testkit::{property, Gen};

// ---------------------------------------------------------------------
// Spatial bundling: word-parallel vs scalar reference
// ---------------------------------------------------------------------

#[test]
fn prop_or_tree_matches_reference() {
    property("bundle_or_pos == scalar reference", 200, |g: &mut Gen| {
        let n = g.range(0, CHANNELS);
        let hvs: Vec<SparseHv> = g.vec(n, |g| g.sparse_hv());
        assert_eq!(bundling::bundle_or_pos(&hvs), bundling::bundle_or_pos_reference(&hvs));
    });
}

#[test]
fn prop_element_counts_match_reference() {
    property("bit-sliced counts (bit/pos) == scalar scatter", 100, |g| {
        let n = g.range(0, CHANNELS);
        let pos: Vec<SparseHv> = g.vec(n, |g| g.sparse_hv());
        let bits: Vec<Hv> = pos.iter().map(|p| p.to_hv()).collect();
        let mut from_bits = SpatialCounts::new();
        let mut from_pos = SpatialCounts::new();
        for (p, h) in pos.iter().zip(bits.iter()) {
            from_pos.add_sparse(p);
            from_bits.add_hv(h);
        }
        assert_eq!(*from_bits.counts(), *bundling::element_counts_reference(&bits));
        assert_eq!(*from_pos.counts(), *bundling::element_counts_pos_reference(&pos));
    });
}

#[test]
fn prop_thin_matches_reference_all_thresholds() {
    property("thin / bit-sliced thin == reference, every threshold", 60, |g| {
        let n = g.range(1, CHANNELS);
        let pos: Vec<SparseHv> = g.vec(n, |g| g.sparse_hv());
        let bits: Vec<Hv> = pos.iter().map(|p| p.to_hv()).collect();
        let counts = bundling::element_counts_reference(&bits);
        let mut acc = SpatialCounts::new();
        for p in &pos {
            acc.add_sparse(p);
        }
        // All reachable thresholds plus the out-of-range tail.
        for t in 0..=(1 << SPATIAL_PLANES) {
            let expect = bundling::thin_reference(&counts, t);
            assert_eq!(bundling::thin(&counts, t), expect, "thin t={t}");
            assert_eq!(acc.thin(t), expect, "bit-sliced thin t={t}");
            assert_eq!(bundling::bundle_adder_thin(&bits, t), expect, "bundle_adder_thin t={t}");
            assert_eq!(bundling::bundle_adder_thin_pos(&pos, t), expect, "adder_thin_pos t={t}");
        }
    });
}

#[test]
fn prop_element_counts_accept_dense_inputs() {
    // The adder tree also bundles arbitrary-density HVs (the baseline
    // variant feeds it bound bit-domain HVs); the bit-sliced counters
    // must match for those too.
    property("dense-input adder tree == reference", 60, |g| {
        let n = g.range(1, 32);
        let hvs: Vec<Hv> = g.vec(n, |g| {
            let d = g.f64();
            g.hv(d)
        });
        let counts = bundling::element_counts_reference(&hvs);
        let mut acc = SpatialCounts::new();
        for hv in &hvs {
            acc.add_hv(hv);
        }
        assert_eq!(*acc.counts(), *counts);
        for t in [0u16, 1, 2, n as u16 / 2 + 1, n as u16, n as u16 + 1] {
            assert_eq!(
                bundling::bundle_adder_thin(&hvs, t),
                bundling::thin_reference(&counts, t),
                "t={t}"
            );
        }
    });
}

// ---------------------------------------------------------------------
// Temporal accumulator: bit-sliced vs scalar reference
// ---------------------------------------------------------------------

#[test]
fn prop_temporal_accumulator_matches_reference() {
    property("bit-sliced temporal == reference (incl. saturation)", 40, |g| {
        let mut fast = TemporalAccumulator::new();
        let mut slow = TemporalAccumulatorReference::new();
        // Past-saturation streams: up to 300 frames of varied density.
        let frames = g.range(1, 300);
        for _ in 0..frames {
            let d = g.f64() * 0.8;
            let f = g.hv(d);
            fast.add(&f);
            slow.add(&f);
        }
        assert_eq!(fast.frames(), slow.frames());
        assert_eq!(*fast.counts(), *slow.counts());
        for t in 0..=(TEMPORAL_COUNTER_MAX + 2) {
            assert_eq!(fast.peek(t), slow.peek(t), "threshold {t}");
        }
        let t = g.range(1, TEMPORAL_COUNTER_MAX as usize) as u16;
        assert_eq!(fast.finish(t), slow.finish(t));
        assert_eq!(*fast.counts(), *slow.counts());
        assert_eq!(fast.frames(), 0);
    });
}

#[test]
fn temporal_saturation_pins_at_counter_max() {
    let mut fast = TemporalAccumulator::new();
    let mut slow = TemporalAccumulatorReference::new();
    let f = Hv::ones();
    for _ in 0..(TEMPORAL_COUNTER_MAX as usize + 50) {
        fast.add(&f);
        slow.add(&f);
    }
    assert_eq!(*fast.counts(), *slow.counts());
    assert!(fast.counts().iter().all(|&c| c == TEMPORAL_COUNTER_MAX));
    assert_eq!(fast.peek(TEMPORAL_COUNTER_MAX), Hv::ones());
    assert_eq!(fast.peek(TEMPORAL_COUNTER_MAX + 1), Hv::zero());
}

// ---------------------------------------------------------------------
// Kernel dispatch tier: every supported KernelSet == scalar, bit-exact
// ---------------------------------------------------------------------

/// Drive one kernel set and the scalar set through identical random
/// workloads and assert bit-exact agreement on every output *and* every
/// side channel (carry-out masks, plane state).
fn assert_set_matches_scalar(ks: &KernelSet, g: &mut Gen) {
    let scalar = KernelSet::scalar();

    // Spatial 7-plane carry-save: same planes, same carry-out word per
    // add — including forced overflow past 127 inputs (dense HVs drive
    // most columns over the top well before add #130).
    let mut a = [[0u64; WORDS]; SPATIAL_PLANES];
    let mut b = a;
    for i in 0..130 {
        let hv = g.hv(0.9);
        let spill_a = (ks.plane_add)(&mut a, &hv);
        let spill_b = (scalar.plane_add)(&mut b, &hv);
        assert_eq!(spill_a, spill_b, "{}: spatial carry-out, add #{i}", ks.name);
    }
    assert_eq!(a, b, "{}: spatial planes after overflow", ks.name);

    // SpatialCounts round trip at sane input counts: counts + every
    // reachable threshold (0 and 2^7 exercise the trivial-edge handling
    // above the kernel, the rest the comparator itself).
    let n = g.range(0, 127);
    let mut fast = SpatialCounts::new();
    let mut slow = SpatialCounts::new();
    for _ in 0..n {
        let hv = g.hv(g.f64() * 0.6);
        fast.add_hv_with(&hv, ks);
        slow.add_hv_with(&hv, scalar);
    }
    assert_eq!(*fast.counts_with(ks), *slow.counts_with(scalar), "{}: counts", ks.name);
    for t in 0..=(1 << SPATIAL_PLANES) {
        assert_eq!(fast.thin_with(t, ks), slow.thin_with(t, scalar), "{}: thin t={t}", ks.name);
    }

    // Temporal 8-plane saturating accumulate: deep past saturation, then
    // every threshold including the 255 saturation edge and transposed
    // counts.
    let mut fast = TemporalAccumulator::new();
    let mut slow = TemporalAccumulator::new();
    let frames = g.range(1, 300);
    for _ in 0..frames {
        let f = g.hv(g.f64() * 0.8);
        fast.add_with(&f, ks);
        slow.add_with(&f, scalar);
    }
    assert_eq!(*fast.counts_with(ks), *slow.counts_with(scalar), "{}: temporal counts", ks.name);
    for t in 0..=(TEMPORAL_COUNTER_MAX + 2) {
        assert_eq!(
            fast.peek_with(t, ks),
            slow.peek_with(t, scalar),
            "{}: temporal thin t={t}",
            ks.name
        );
    }

    // Raw ge_threshold / transpose over hand-packed plane state (the
    // accumulators above never produce *arbitrary* plane bits; random
    // planes do).
    let mut planes = [[0u64; WORDS]; TEMPORAL_PLANES];
    for plane in planes.iter_mut() {
        for w in plane.iter_mut() {
            *w = g.hv(0.5).words[0];
        }
    }
    assert_eq!(
        *(ks.transpose_counts)(&planes),
        *(scalar.transpose_counts)(&planes),
        "{}: transpose of random planes",
        ks.name
    );
    for t in 1..=TEMPORAL_COUNTER_MAX {
        assert_eq!(
            (ks.ge_threshold)(&planes, t as u64),
            (scalar.ge_threshold)(&planes, t as u64),
            "{}: ge_threshold t={t} on random planes",
            ks.name
        );
    }

    // Fused two-class scoring against the Hv methods.
    let q = g.hv(g.f64());
    let c0 = g.hv(g.f64());
    let c1 = g.hv(g.f64());
    assert_eq!(
        (ks.overlap2)(&q, &c0, &c1),
        [q.overlap(&c0), q.overlap(&c1)],
        "{}: overlap2",
        ks.name
    );
    assert_eq!(
        (ks.hamming2)(&q, &c0, &c1),
        [q.hamming(&c0), q.hamming(&c1)],
        "{}: hamming2",
        ks.name
    );
}

#[test]
fn prop_every_supported_set_matches_scalar_bit_exactly() {
    for ks in KernelSet::supported() {
        property(&format!("kernel set {} == scalar", ks.name), 30, |g| {
            assert_set_matches_scalar(ks, g);
        });
    }
}

/// The satellite's explicit form: whatever `auto()` resolved to on this
/// machine agrees with scalar bit-exactly (redundant with the loop above
/// when auto is in `supported()`, but this is the property the dispatch
/// default actually relies on — keep it named).
#[test]
fn prop_auto_set_matches_scalar_bit_exactly() {
    property("KernelSet::auto() == KernelSet::scalar()", 30, |g| {
        assert_set_matches_scalar(KernelSet::auto(), g);
    });
}

#[test]
fn search_batch_matches_serial_oracle_at_edge_sizes() {
    // Batch sizes 0 / 1 / odd / beyond the engine-pool queue depth (64),
    // both metrics, every supported set: the batched fused path must
    // agree with per-query scalar search exactly.
    property("search_batch_with == per-query scalar", 10, |g: &mut Gen| {
        let am = AssociativeMemory::new(g.hv(0.5), g.hv(0.5));
        let scalar = KernelSet::scalar();
        for &n in &[0usize, 1, 7, 129] {
            let queries: Vec<Hv> = g.vec(n, |g| g.hv(0.25));
            for metric in [Metric::Overlap, Metric::Hamming] {
                let expect = am.search_batch_with(&queries, metric, scalar);
                assert_eq!(expect.len(), n);
                // The batched path itself matches the serial entry points.
                let serial: Vec<_> = queries
                    .iter()
                    .map(|q| match metric {
                        Metric::Overlap => am.search(q),
                        Metric::Hamming => am.search_dense(q),
                    })
                    .collect();
                assert_eq!(expect, serial, "batch {n}, {metric:?} vs serial");
                for ks in KernelSet::supported() {
                    let got = am.search_batch_with(&queries, metric, ks);
                    assert_eq!(got, expect, "{}: batch {n}, {metric:?}", ks.name);
                }
            }
        }
    });
}

#[test]
fn active_set_honours_the_env_override() {
    // The forced-kernel CI legs (`HDC_KERNELS=scalar` / `=avx2`) rely on
    // this: the process-wide active set is exactly what the env asked
    // for, or `auto()` when unset. A bad/unsupported value panics inside
    // `active()` before this assert — which is also what the legs want
    // (no silent downgrade to a set that wasn't exercised).
    let active = simd::active();
    match std::env::var("HDC_KERNELS") {
        Ok(name) if name != "auto" => assert_eq!(active.name, name),
        _ => assert_eq!(active.name, KernelSet::auto().name),
    }
    // And the pin is sticky: re-selecting the same name is fine.
    simd::select(active.name).expect("re-selecting the active set is idempotent");
}

// ---------------------------------------------------------------------
// Evaluation pool: parallel output == serial output, same order
// ---------------------------------------------------------------------

fn synthetic_cohort(n: usize) -> Vec<SynthPatient> {
    let synth = SynthConfig {
        records_per_patient: 2,
        pre_s: 6.0,
        ictal_s: 4.0,
        post_s: 2.0,
        ..Default::default()
    };
    (1..=n as u32)
        .map(|pid| SynthPatient::generate(&synth, pid))
        .collect()
}

fn assert_evals_equal(parallel: &[PatientEval], serial: &[PatientEval]) {
    assert_eq!(parallel.len(), serial.len());
    for (p, s) in parallel.iter().zip(serial.iter()) {
        assert_eq!(p.patient_id, s.patient_id, "result order must be input order");
        assert_eq!(p.temporal_threshold, s.temporal_threshold);
        assert_eq!(p.summary.detected, s.summary.detected);
        assert_eq!(p.summary.seizures, s.summary.seizures);
        assert_eq!(p.summary.false_alarms, s.summary.false_alarms);
        assert_eq!(p.summary.mean_delay_s().to_bits(), s.summary.mean_delay_s().to_bits());
        assert_eq!(
            p.mean_query_density.to_bits(),
            s.mean_query_density.to_bits(),
            "bit-exact density"
        );
    }
}

#[test]
fn evalpool_matches_serial_evaluation() {
    let patients = synthetic_cohort(3);
    let policy = AlarmPolicy { consecutive: 1 };
    // The full (variant × max-density × patient) job shape the sweep
    // commands shard.
    let jobs: Vec<(Variant, Option<f64>, usize)> = [
        (Variant::Optimized, Some(0.15)),
        (Variant::Optimized, Some(0.30)),
        (Variant::SparseCompIm, Some(0.30)),
        (Variant::DenseBaseline, None),
    ]
    .iter()
    .flat_map(|&(v, d)| (0..patients.len()).map(move |i| (v, d, i)))
    .collect();

    let eval = |&(variant, max_d, i): &(Variant, Option<f64>, usize)| {
        let cfg = if variant == Variant::Optimized {
            ClassifierConfig::optimized()
        } else {
            ClassifierConfig::default()
        };
        pipeline::evaluate_patient(variant, &cfg, &patients[i], max_d, policy)
    };

    let serial = evalpool::map_with(1, &jobs, eval);
    let parallel = evalpool::map_with(4, &jobs, eval);
    assert_evals_equal(&parallel, &serial);
}

#[test]
fn evalpool_ordering_is_input_order_under_skew() {
    // Jobs finishing out of order (patient sizes differ) must not reorder
    // results.
    let patients = synthetic_cohort(5);
    let jobs: Vec<usize> = (0..patients.len()).rev().collect();
    let ids = evalpool::map_with(3, &jobs, |&i| patients[i].profile.id);
    let expect: Vec<u32> = jobs.iter().map(|&i| patients[i].profile.id).collect();
    assert_eq!(ids, expect);
}
