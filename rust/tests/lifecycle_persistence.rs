//! Durable-model-fleet tests: the [`ModelStore`] persistence backend
//! (scan ≡ the publish sequence that produced the directory, crash
//! recovery) and the serve → kill → serve-from-`--models-dir` round
//! trip, pinned window for window against an uninterrupted run.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use sparse_hdc_ieeg::config::SystemConfig;
use sparse_hdc_ieeg::coordinator::registry::{ModelRegistry, ModelStore};
use sparse_hdc_ieeg::coordinator::scheduler::{RetrainPolicy, RetrainScheduler};
use sparse_hdc_ieeg::coordinator::server::{Backend, Coordinator, StreamSpec};
use sparse_hdc_ieeg::data::metrics::WindowPrediction;
use sparse_hdc_ieeg::data::synth::SynthPatient;
use sparse_hdc_ieeg::hdc::am::AssociativeMemory;
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Variant};
use sparse_hdc_ieeg::hdc::model::{ModelBundle, Provenance};
use sparse_hdc_ieeg::testkit::{property, scratch_dir, tiny_trained_patient, Gen};

fn store_dir(tag: &str) -> PathBuf {
    scratch_dir(&format!("persist_{tag}"))
}

/// A small synthetic bundle (no training pass) for store-level tests.
fn synthetic_bundle(g: &mut Gen, patient_id: u32, version: u64) -> ModelBundle {
    let mut b = ModelBundle::new(
        Variant::Optimized,
        ClassifierConfig::optimized(),
        AssociativeMemory::new(g.hv(0.3), g.hv(0.2)),
        Provenance {
            patient_id,
            epochs: g.usize_below(5) as u32,
            parent_version: version.saturating_sub(1),
            train_windows: [g.u64() % 300, g.u64() % 300],
            note: format!("synthetic v{version}"),
        },
    );
    b.version = version;
    if g.bool(0.5) {
        b.counters = Some(g.counter_planes());
    }
    b
}

/// Property: after any publish sequence, `scan` recovers exactly the
/// highest version written per patient — the directory is a faithful
/// replay of the sequence, nothing quarantined, nothing invented.
#[test]
fn scan_equals_publish_sequence() {
    property("ModelStore scan ≡ publish sequence", 16, |g: &mut Gen| {
        let dir = store_dir(&format!("prop_{:x}", g.case_seed));
        let store = ModelStore::open(&dir).unwrap();
        let mut latest: BTreeMap<u32, ModelBundle> = BTreeMap::new();
        let mut next_version: BTreeMap<u32, u64> = BTreeMap::new();

        let publishes = g.range(1, 10);
        for _ in 0..publishes {
            let pid = 1 + g.usize_below(3) as u32;
            let version = next_version.entry(pid).or_insert(0);
            *version += 1 + g.usize_below(2) as u64; // gaps are legal
            let bundle = synthetic_bundle(g, pid, *version);
            store.save(&bundle).unwrap();
            latest.insert(pid, bundle);
        }

        let scan = store.scan().unwrap();
        assert!(scan.quarantined.is_empty(), "clean store must not quarantine");
        assert!(scan.ignored.is_empty());
        assert_eq!(scan.recovered.len(), latest.len());
        for (pid, bundle) in &latest {
            assert_eq!(&scan.recovered[pid], bundle, "patient {pid}");
        }
        std::fs::remove_dir_all(&dir).ok();
    });
}

/// Crash simulation: a leftover `.tmp` from an interrupted publish plus
/// a truncated highest version — the scan must fall back to the newest
/// valid version, quarantine the truncated file, ignore the tmp, and be
/// idempotent about it.
#[test]
fn crash_leftovers_fall_back_to_newest_valid() {
    let dir = store_dir("crash");
    let store = ModelStore::open(&dir).unwrap();
    let mut g = Gen::new(0xC9A5);
    let v1 = synthetic_bundle(&mut g, 9, 1);
    let v2 = synthetic_bundle(&mut g, 9, 2);
    let v3 = synthetic_bundle(&mut g, 9, 3);
    store.save(&v1).unwrap();
    store.save(&v2).unwrap();
    store.save(&v3).unwrap();

    // Truncate the newest version at half its bytes (the crash window a
    // non-atomic writer would have had) and strand a tmp publish.
    let v3_path = store.version_path(9, 3);
    let bytes = std::fs::read(&v3_path).unwrap();
    std::fs::write(&v3_path, &bytes[..bytes.len() / 2]).unwrap();
    std::fs::write(dir.join("9").join(".v004.hdcm.tmp"), b"interrupted").unwrap();

    let scan = store.scan().unwrap();
    assert_eq!(scan.recovered[&9], v2, "newest *valid* version wins");
    assert_eq!(scan.quarantined.len(), 1);
    assert!(scan.quarantined[0].ends_with("v003.hdcm.corrupt"), "{:?}", scan.quarantined);
    assert!(!v3_path.exists(), "truncated file renamed out of the way");
    assert_eq!(scan.ignored.len(), 1, "tmp leftover ignored: {:?}", scan.ignored);

    // Idempotent: nothing new to quarantine, same recovery.
    let again = store.scan().unwrap();
    assert_eq!(again.recovered[&9], v2);
    assert!(again.quarantined.is_empty());

    // A re-publish of v3 (e.g. the retrain re-runs after restart) heals
    // the store: the atomic rename lands a complete file.
    store.save(&v3).unwrap();
    assert_eq!(store.scan().unwrap().recovered[&9], v3);
    std::fs::remove_dir_all(&dir).ok();
}

fn run_stream(bundle: ModelBundle, patient: &SynthPatient, pid: u32) -> Vec<WindowPrediction> {
    Coordinator::new(SystemConfig::default(), Backend::Native)
        .run(vec![StreamSpec {
            session_id: 1,
            patient_id: pid,
            record: patient.records[1].clone(),
            bundle,
        }])
        .unwrap()
        .sessions
        .remove(0)
        .predictions
}

/// The serve → kill → serve-from-`--models-dir` acceptance pin, at the
/// coordinator level (CI exercises the real SIGTERM through the binary):
///
/// 1. serve run A persists v1 at startup and — via a triggered retrain —
///    persists + publishes v2 mid-stream;
/// 2. "kill": run A's registry and coordinator are dropped; only the
///    store directory survives;
/// 3. serve run B scans the store, resumes at v2, and its stream is
///    pinned **window for window** against an uninterrupted run of the
///    exact in-memory v2 that run A published.
#[test]
fn serve_kill_resume_round_trip_pins_windows() {
    let pid = 21;
    let (patient, v1) = tiny_trained_patient(pid);
    let dir = store_dir("resume");
    let store = Arc::new(ModelStore::open(&dir).unwrap());

    // --- run A: persist v1, trigger one foreground retrain → v2. ---
    let registry = Arc::new(ModelRegistry::new());
    store.save(&v1).unwrap();
    let mut train = BTreeMap::new();
    train.insert(pid, patient.records[0].clone());
    let scheduler = Arc::new(
        RetrainScheduler::new(
            RetrainPolicy {
                epochs: 3,
                fa_window: 4,
                fa_rate: 0.0,
                cooldown: 100_000,
                max_retrains: 1,
            },
            registry.clone(),
            Some(store.clone()),
            train,
        )
        .foreground(),
    );
    let mut coordinator = Coordinator::new(SystemConfig::default(), Backend::Native);
    coordinator.scheduler = Some(scheduler.clone());
    let interrupted = coordinator
        .run_with_registry(
            vec![StreamSpec {
                session_id: 1,
                patient_id: pid,
                record: patient.records[1].clone(),
                bundle: v1.clone(),
            }],
            &registry,
            |_| {},
        )
        .unwrap();
    assert_eq!(scheduler.triggers(), vec![(pid, 4)]);
    assert_eq!(interrupted.metrics.retrains_triggered, 1);
    let msgs = scheduler.join();
    assert!(msgs[0].contains("published model v2"), "{:?}", msgs);
    let published_v2 = registry.current(pid).unwrap().bundle.clone();
    assert_eq!(published_v2.version, 2);
    assert!(published_v2.counters.is_some(), "retrained bundles persist their planes");

    // --- "kill": drop everything in-memory; the store is the survivor. ---
    drop((registry, coordinator, scheduler));

    // --- run B: a fresh scan recovers exactly the published v2… ---
    let scan = ModelStore::open(&dir).unwrap().scan().unwrap();
    let recovered = scan.recovered[&pid].clone();
    assert_eq!(recovered, published_v2, "disk round-trip is bit-faithful");

    // …and serving the recovered artifact is pinned window for window
    // against an uninterrupted run of the in-memory v2.
    let resumed = run_stream(recovered, &patient, pid);
    let uninterrupted = run_stream(published_v2, &patient, pid);
    assert_eq!(resumed.len(), uninterrupted.len());
    assert_eq!(resumed, uninterrupted, "resume must not shift a single window");
    std::fs::remove_dir_all(&dir).ok();
}
