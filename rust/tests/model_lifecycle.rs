//! Model-lifecycle integration tests: bundle persistence round-trips,
//! corruption handling (including an adversarial byte-flip fuzz over
//! every offset of both format versions and the v2→v1 cross-read
//! matrix), serving from a saved artifact (no startup retraining),
//! online retraining guarantees, and mid-stream registry hot swap under
//! the coalescing engine host.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use sparse_hdc_ieeg::config::SystemConfig;
use sparse_hdc_ieeg::coordinator::registry::ModelRegistry;
use sparse_hdc_ieeg::coordinator::server::{Backend, Coordinator, StreamSpec, StreamReport};
use sparse_hdc_ieeg::hdc::am::AssociativeMemory;
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Variant};
use sparse_hdc_ieeg::hdc::hv::Hv;
use sparse_hdc_ieeg::hdc::model::{ModelBundle, Provenance, BASE_FORMAT_VERSION, FORMAT_VERSION};
use sparse_hdc_ieeg::pipeline;
use sparse_hdc_ieeg::rng::Xoshiro256;
use sparse_hdc_ieeg::testkit::tiny_trained_patient;

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hdc_ml_{tag}_{}.hdcm", std::process::id()))
}

/// A randomized bundle; even cases carry counter planes (format 2), odd
/// cases are counter-less (format 1).
fn random_bundle(rng: &mut Xoshiro256, case: u64) -> ModelBundle {
    let density = 0.05 + (case as f64 % 7.0) * 0.07;
    ModelBundle {
        version: 1 + rng.next_below(1000),
        variant: if case % 2 == 0 { Variant::Optimized } else { Variant::SparseCompIm },
        config: ClassifierConfig {
            seed: rng.next_u64(),
            spatial_threshold: (rng.next_below(4) + 1) as u16,
            temporal_threshold: rng.next_below(256) as u16,
            train_density: density,
        },
        am: AssociativeMemory::new(Hv::random(rng, density), Hv::random(rng, density)),
        provenance: Provenance {
            patient_id: rng.next_below(100) as u32,
            epochs: rng.next_below(9) as u32,
            parent_version: rng.next_below(10),
            train_windows: [rng.next_below(500), rng.next_below(500)],
            note: format!("case {case} — note with ümlauts / #hash / \"quotes\""),
        },
        counters: if case % 2 == 0 {
            Some(sparse_hdc_ieeg::testkit::random_counter_planes(rng))
        } else {
            None
        },
    }
}

/// Property: save → load is bit-identical for randomized bundles of both
/// format versions (AM planes, thresholds, seeds, provenance, counter
/// planes — the full artifact).
#[test]
fn bundle_roundtrip_property() {
    let mut rng = Xoshiro256::new(0xB00B1E5);
    for case in 0..24u64 {
        let bundle = random_bundle(&mut rng, case);
        let bytes = bundle.to_bytes();
        let back = ModelBundle::from_bytes(&bytes).unwrap_or_else(|e| {
            panic!("case {case}: roundtrip failed: {e:#}");
        });
        assert_eq!(back, bundle, "case {case}");
        assert_eq!(back.am.classes[0], bundle.am.classes[0]);
        assert_eq!(back.am.classes[1], bundle.am.classes[1]);
        assert_eq!(back.counters, bundle.counters, "case {case}");
        assert_eq!(back.wire_format(), if case % 2 == 0 { 2 } else { 1 });
    }
}

#[test]
fn corrupt_files_fail_actionably() {
    // Not-a-bundle file.
    let path = tmpfile("garbage");
    std::fs::write(&path, b"definitely not a model bundle").unwrap();
    let err = ModelBundle::load(&path).unwrap_err();
    assert!(format!("{err:#}").contains(path.to_str().unwrap()), "{err:#}");
    std::fs::remove_file(&path).ok();

    // Truncated on disk: every prefix fails, never panics.
    let (_, bundle) = tiny_trained_patient(1);
    let bytes = bundle.to_bytes();
    let path = tmpfile("trunc");
    for frac in [1, 3, 7, 9] {
        std::fs::write(&path, &bytes[..bytes.len() * frac / 10]).unwrap();
        assert!(ModelBundle::load(&path).is_err(), "prefix {frac}0% must fail");
    }
    // Flipped format version is told apart from truncation.
    let mut patched = bytes.clone();
    patched[4..8].copy_from_slice(&7u32.to_le_bytes());
    std::fs::write(&path, &patched).unwrap();
    let err = ModelBundle::load(&path).unwrap_err();
    assert!(format!("{err:#}").contains("format version 7"), "{err:#}");
    std::fs::remove_file(&path).ok();
}

/// Flip fuzz core: for every byte offset of `bytes`, apply
/// `flips_per_offset` seeded random single-byte corruptions and parse.
/// The parser must return `Err` or a semantically valid bundle — never
/// panic (caught and re-raised with the reproducing offset/mask) and
/// never allocate from the corrupted length fields (all allocations in
/// the parser are fixed-size; lengths are bounds-checked against the
/// file before any payload is touched). A parse that succeeds must
/// round-trip: serialize → parse → the same bundle.
fn byte_flip_fuzz(bytes: &[u8], seed: u64, flips_per_offset: usize) {
    let mut rng = Xoshiro256::new(seed);
    let mut survived = 0usize;
    for offset in 0..bytes.len() {
        for _ in 0..flips_per_offset {
            // Non-zero XOR mask: the byte always actually changes.
            let mask = (rng.next_below(255) + 1) as u8;
            let mut mutated = bytes.to_vec();
            mutated[offset] ^= mask;
            let outcome = std::panic::catch_unwind(|| ModelBundle::from_bytes(&mutated));
            match outcome {
                Err(_) => panic!(
                    "parser panicked at offset {offset} (xor {mask:#04x}, seed {seed:#x})"
                ),
                Ok(Ok(bundle)) => {
                    survived += 1;
                    let rt = ModelBundle::from_bytes(&bundle.to_bytes()).unwrap_or_else(|e| {
                        panic!(
                            "offset {offset} (xor {mask:#04x}): accepted bundle does not \
                             re-parse: {e:#}"
                        )
                    });
                    assert_eq!(rt, bundle, "offset {offset}: accepted bundle must round-trip");
                }
                Ok(Err(_)) => {} // rejected cleanly — the common case
            }
        }
    }
    // Sanity: flips inside free-form payload bytes (note text, counter
    // values) must survive as valid bundles — an all-rejecting parser
    // would also "pass" the panic check.
    assert!(survived > 0, "no single-byte flip ever produced a valid bundle");
}

/// Every offset of a format-2 bundle, one seeded flip each — fast enough
/// for the default test run.
#[test]
fn byte_flips_never_panic_v2() {
    let mut rng = Xoshiro256::new(0xF1_1B);
    byte_flip_fuzz(&random_bundle(&mut rng, 0).to_bytes(), 0xA5A5_0001, 1);
}

/// Every offset of a format-1 bundle, one seeded flip each.
#[test]
fn byte_flips_never_panic_v1() {
    let mut rng = Xoshiro256::new(0xF1_1C);
    byte_flip_fuzz(&random_bundle(&mut rng, 1).to_bytes(), 0xA5A5_0002, 1);
}

/// The exhaustive adversarial pass: several independent flips per offset
/// over multiple randomized bundles of both format versions. CI runs it
/// via `cargo test -q -- --include-ignored`.
#[test]
#[ignore = "exhaustive byte-flip fuzz (CI runs it with --include-ignored)"]
fn byte_flip_fuzz_exhaustive_both_formats() {
    let mut rng = Xoshiro256::new(0xFA_57);
    for case in 0..4u64 {
        let bytes = random_bundle(&mut rng, case).to_bytes();
        byte_flip_fuzz(&bytes, 0xE8_0A57 ^ case, 4);
    }
}

/// Walk the section table of a serialized bundle, applying `f` to each
/// (tag-offset, len) pair — the test-side mirror of the parser's layout.
fn for_each_section(bytes: &[u8], mut f: impl FnMut(usize, usize)) {
    let n = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let mut off = 12;
    for _ in 0..n {
        let len = u32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap()) as usize;
        f(off, len);
        off += 8 + len;
    }
}

/// The v2 → v1 cross-read matrix, pinning the unknown-section skip rule
/// both ways:
///
/// * a v2 reader over v1 bytes recovers everything, counters absent;
/// * a reader that does **not** know `CNTP` (simulated by renaming the
///   tag to one no reader knows and patching the header back to format
///   1 — exactly what a format-1 binary sees modulo the tag name)
///   recovers the v1 content of a v2 bundle via the skip rule;
/// * v2 bytes parse completely, counters present;
/// * formats beyond this build fail actionably.
#[test]
fn v2_v1_cross_read_matrix() {
    let mut rng = Xoshiro256::new(0xC0FE);
    let v2 = random_bundle(&mut rng, 0);
    assert!(v2.counters.is_some());
    let mut v1_content = v2.clone();
    v1_content.counters = None;

    let v1_bytes = v1_content.to_bytes();
    let v2_bytes = v2.to_bytes();
    assert_eq!(v1_bytes[4..8], BASE_FORMAT_VERSION.to_le_bytes());
    assert_eq!(v2_bytes[4..8], FORMAT_VERSION.to_le_bytes());

    // v2 reader ← v1 bytes: counters None, everything else intact.
    let up = ModelBundle::from_bytes(&v1_bytes).unwrap();
    assert_eq!(up, v1_content);
    assert!(up.counters.is_none());

    // v2 reader ← v2 bytes: the full artifact.
    assert_eq!(ModelBundle::from_bytes(&v2_bytes).unwrap(), v2);

    // "v1 reader" ← v2 bytes: rename CNTP to an unknown tag and set the
    // header to format 1 — the skip rule must recover the v1 content.
    let mut downgraded = v2_bytes.clone();
    downgraded[4..8].copy_from_slice(&BASE_FORMAT_VERSION.to_le_bytes());
    for_each_section(&v2_bytes, |off, _| {
        if &v2_bytes[off..off + 4] == b"CNTP" {
            downgraded[off..off + 4].copy_from_slice(b"ZZZZ");
        }
    });
    let down = ModelBundle::from_bytes(&downgraded).unwrap();
    assert_eq!(down, v1_content, "skip rule must yield exactly the v1 content");

    // CNTP is self-describing: even under a format-1 header the section
    // parses when present (sections, not the header, carry the schema).
    let mut header_only = v2_bytes.clone();
    header_only[4..8].copy_from_slice(&BASE_FORMAT_VERSION.to_le_bytes());
    assert_eq!(ModelBundle::from_bytes(&header_only).unwrap(), v2);

    // A future format fails loudly with the supported range.
    let mut future = v2_bytes;
    future[4..8].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
    let err = ModelBundle::from_bytes(&future).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains(&format!("format version {}", FORMAT_VERSION + 1)), "{msg}");
    assert!(msg.contains(&FORMAT_VERSION.to_string()), "{msg}");
}

/// Section-length adversarial cases the random flips may miss: every
/// section's length field forced to huge / overlapping values must be
/// rejected by the pre-allocation bounds check.
#[test]
fn hostile_section_lengths_rejected() {
    let mut rng = Xoshiro256::new(0x1E57);
    for case in 0..2u64 {
        let bytes = random_bundle(&mut rng, case).to_bytes();
        let mut offsets = Vec::new();
        for_each_section(&bytes, |off, _| offsets.push(off));
        for off in offsets {
            for hostile in [u32::MAX, bytes.len() as u32, 0x7FFF_FFFF] {
                let mut m = bytes.clone();
                m[off + 4..off + 8].copy_from_slice(&hostile.to_le_bytes());
                assert!(
                    ModelBundle::from_bytes(&m).is_err(),
                    "case {case}: hostile len {hostile:#x} at section offset {off} must fail"
                );
            }
        }
        // A hostile section *count* walks off the table and fails too.
        let mut m = bytes.clone();
        m[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(ModelBundle::from_bytes(&m).is_err());
    }
}

/// The acceptance pin: serving from a saved bundle skips retraining and
/// is bit-identical — window for window — to the retrain-at-startup
/// path with the same seed/config.
#[test]
fn serving_from_saved_bundle_matches_retrain_at_startup() {
    let (patient, bundle) = tiny_trained_patient(7);

    // Save → load: the artifact that `repro serve --model` deploys.
    let path = tmpfile("serve");
    bundle.save(&path).unwrap();
    let loaded = ModelBundle::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, bundle, "the loaded artifact is the trained model, bit for bit");

    let spec = |bundle: ModelBundle| StreamSpec {
        session_id: 1,
        patient_id: 7,
        record: patient.records[1].clone(),
        bundle,
    };
    let run = |b: ModelBundle| -> StreamReport {
        Coordinator::new(SystemConfig::default(), Backend::Native)
            .run(vec![spec(b)])
            .unwrap()
    };
    let fresh = run(bundle);
    let saved = run(loaded);

    assert_eq!(fresh.sessions[0].predictions, saved.sessions[0].predictions);
    assert_eq!(fresh.sessions[0].eval.detected, saved.sessions[0].eval.detected);
    assert_eq!(fresh.sessions[0].eval.delay_s, saved.sessions[0].eval.delay_s);
    assert_eq!(fresh.sessions[0].eval.false_alarms, saved.sessions[0].eval.false_alarms);
    assert_eq!(fresh.sessions[0].model_version, saved.sessions[0].model_version);
}

/// The acceptance pin for the retrainer: the published next version
/// scores no worse than one-shot on the training windows (keep-best),
/// and versions stay monotone through the registry.
#[test]
fn online_retrain_improves_or_preserves_and_versions_monotone() {
    let (patient, bundle) = tiny_trained_patient(3);
    let (next, report) = pipeline::retrain_bundle(
        &bundle,
        patient.train_record(),
        &pipeline::RetrainOptions::default(),
    );
    assert_eq!(next.version, 2);
    assert_eq!(next.provenance.parent_version, 1);
    assert!(
        report.best_errors <= report.initial_errors,
        "retrain must not degrade training-window accuracy \
         ({} -> {})",
        report.initial_errors,
        report.best_errors
    );
    // Measured independently with a fresh encode pass.
    let trainer = pipeline::online_trainer_for_record(
        Variant::Optimized,
        &next.config,
        patient.train_record(),
    );
    assert!(trainer.errors(&next.am) <= trainer.errors(&bundle.am));

    // Registry: v1 then v2 publish fine; re-publishing v1 afterwards is
    // rejected as stale.
    let registry = ModelRegistry::new();
    registry.publish(3, bundle.clone()).unwrap();
    registry.publish(3, next).unwrap();
    assert!(registry.publish(3, bundle).is_err());
    assert_eq!(registry.current(3).unwrap().version(), 2);
}

/// The hot-swap pin: publish v2 (class HVs swapped, so predictions
/// flip) mid-stream through the registry, and the served prediction
/// stream must equal v1's predictions up to the (deterministic) swap
/// boundary and v2's from it on — exercised under the coalescing
/// `EngineHost` with submission-order delivery, zero queue drain.
#[test]
fn mid_stream_swap_changes_results_only_at_the_boundary() {
    let (patient, v1) = tiny_trained_patient(5);
    // v2: same encoder config, classes swapped — flips every decision.
    let mut v2 = v1.clone();
    v2.version = 2;
    v2.provenance.parent_version = 1;
    v2.am = AssociativeMemory::new(v1.am.classes[1], v1.am.classes[0]);

    let spec = |bundle: ModelBundle| StreamSpec {
        session_id: 1,
        patient_id: 5,
        record: patient.records[1].clone(),
        bundle,
    };
    let run_pure = |b: ModelBundle| -> Vec<sparse_hdc_ieeg::data::metrics::WindowPrediction> {
        Coordinator::new(SystemConfig::default(), Backend::Native)
            .run(vec![spec(b)])
            .unwrap()
            .sessions
            .remove(0)
            .predictions
    };
    let preds_v1 = run_pure(v1.clone());
    let preds_v2 = run_pure(v2.clone());
    assert_eq!(preds_v1.len(), preds_v2.len());
    assert_ne!(preds_v1, preds_v2, "class-swapped model must predict differently");

    // Swapped run: publish v2 once the first micro-batch (4 windows,
    // the SystemConfig default) has been submitted. The next batch picks
    // it up, so the boundary sits at window 4 exactly.
    let registry = Arc::new(ModelRegistry::new());
    let published = AtomicBool::new(false);
    let reg = registry.clone();
    let v2_for_hook = v2.clone();
    let coordinator = Coordinator::new(SystemConfig::default(), Backend::Native);
    let report = coordinator
        .run_with_registry(vec![spec(v1.clone())], &registry, move |windows_submitted| {
            if windows_submitted >= 4 && !published.swap(true, Ordering::Relaxed) {
                reg.publish(5, v2_for_hook.clone()).unwrap();
            }
        })
        .unwrap();

    let session = &report.sessions[0];
    assert_eq!(session.model_version, 2, "stream must end on the new version");
    assert_eq!(session.model_swaps, 1);
    assert_eq!(report.metrics.model_swaps, 1);
    assert_eq!(report.metrics.windows_failed, 0, "zero drain: nothing is lost at the swap");

    let boundary = 4usize;
    assert_eq!(
        &session.predictions[..boundary],
        &preds_v1[..boundary],
        "windows before the swap boundary must come from v1"
    );
    assert_eq!(
        &session.predictions[boundary..],
        &preds_v2[boundary..],
        "windows from the swap boundary on must come from v2"
    );
}

/// Registry sharing across sessions of one patient: both sessions see
/// the same published instance and swap together.
#[test]
fn two_sessions_of_one_patient_share_the_published_model() {
    let (patient, bundle) = tiny_trained_patient(9);
    let specs = vec![
        StreamSpec {
            session_id: 1,
            patient_id: 9,
            record: patient.records[1].clone(),
            bundle: bundle.clone(),
        },
        StreamSpec {
            session_id: 2,
            patient_id: 9,
            record: patient.records[1].clone(),
            bundle,
        },
    ];
    let report = Coordinator::new(SystemConfig::default(), Backend::Native)
        .run(specs)
        .unwrap();
    assert_eq!(report.sessions.len(), 2);
    assert_eq!(
        report.sessions[0].predictions, report.sessions[1].predictions,
        "same patient, same record, same published model → same stream"
    );
}

/// Two *different* bundles at the same (patient, version) are ambiguous
/// — the registry slot is per patient, so serving must reject instead
/// of silently running the second session on the first session's model.
#[test]
fn conflicting_bundles_for_one_patient_are_rejected() {
    let (patient, bundle) = tiny_trained_patient(13);
    let mut other = bundle.clone();
    other.am = AssociativeMemory::new(other.am.classes[1], other.am.classes[0]);
    let specs = vec![
        StreamSpec {
            session_id: 1,
            patient_id: 13,
            record: patient.records[1].clone(),
            bundle,
        },
        StreamSpec {
            session_id: 2,
            patient_id: 13,
            record: patient.records[1].clone(),
            bundle: other,
        },
    ];
    let err = Coordinator::new(SystemConfig::default(), Backend::Native)
        .run(specs)
        .expect_err("conflicting same-version bundles must not serve");
    let msg = format!("{err:#}");
    assert!(msg.contains("patient 13"), "{msg}");
    assert!(msg.contains("version"), "{msg}");
}
