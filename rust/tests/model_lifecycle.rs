//! Model-lifecycle integration tests: bundle persistence round-trips,
//! corruption handling, serving from a saved artifact (no startup
//! retraining), online retraining guarantees, and mid-stream registry
//! hot swap under the coalescing engine host.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use sparse_hdc_ieeg::config::SystemConfig;
use sparse_hdc_ieeg::coordinator::registry::ModelRegistry;
use sparse_hdc_ieeg::coordinator::server::{Backend, Coordinator, StreamSpec, StreamReport};
use sparse_hdc_ieeg::data::synth::{SynthConfig, SynthPatient};
use sparse_hdc_ieeg::hdc::am::AssociativeMemory;
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, SparseEncoder, Variant};
use sparse_hdc_ieeg::hdc::hv::Hv;
use sparse_hdc_ieeg::hdc::model::{ModelBundle, Provenance};
use sparse_hdc_ieeg::pipeline;
use sparse_hdc_ieeg::rng::Xoshiro256;

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hdc_ml_{tag}_{}.hdcm", std::process::id()))
}

fn tiny_synth() -> SynthConfig {
    SynthConfig {
        records_per_patient: 2,
        pre_s: 4.0,
        ictal_s: 3.0,
        post_s: 1.0,
        ..Default::default()
    }
}

fn trained_bundle(pid: u32) -> (SynthPatient, ModelBundle) {
    let patient = SynthPatient::generate(&tiny_synth(), pid);
    let cfg = ClassifierConfig::optimized();
    let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
    let mut bundle = pipeline::train_on_record(&mut enc, patient.train_record(), &cfg);
    bundle.provenance.patient_id = pid;
    (patient, bundle)
}

/// Property: save → load is bit-identical for randomized bundles (AM
/// planes, thresholds, seeds, provenance — the full artifact).
#[test]
fn bundle_roundtrip_property() {
    let mut rng = Xoshiro256::new(0xB00B1E5);
    for case in 0..24u64 {
        let density = 0.05 + (case as f64 % 7.0) * 0.07;
        let bundle = ModelBundle {
            version: 1 + rng.next_below(1000),
            variant: if case % 2 == 0 { Variant::Optimized } else { Variant::SparseCompIm },
            config: ClassifierConfig {
                seed: rng.next_u64(),
                spatial_threshold: (rng.next_below(4) + 1) as u16,
                temporal_threshold: rng.next_below(256) as u16,
                train_density: density,
            },
            am: AssociativeMemory::new(
                Hv::random(&mut rng, density),
                Hv::random(&mut rng, density),
            ),
            provenance: Provenance {
                patient_id: rng.next_below(100) as u32,
                epochs: rng.next_below(9) as u32,
                parent_version: rng.next_below(10),
                train_windows: [rng.next_below(500), rng.next_below(500)],
                note: format!("case {case} — note with ümlauts / #hash / \"quotes\""),
            },
        };
        let bytes = bundle.to_bytes();
        let back = ModelBundle::from_bytes(&bytes).unwrap_or_else(|e| {
            panic!("case {case}: roundtrip failed: {e:#}");
        });
        assert_eq!(back, bundle, "case {case}");
        assert_eq!(back.am.classes[0], bundle.am.classes[0]);
        assert_eq!(back.am.classes[1], bundle.am.classes[1]);
    }
}

#[test]
fn corrupt_files_fail_actionably() {
    // Not-a-bundle file.
    let path = tmpfile("garbage");
    std::fs::write(&path, b"definitely not a model bundle").unwrap();
    let err = ModelBundle::load(&path).unwrap_err();
    assert!(format!("{err:#}").contains(path.to_str().unwrap()), "{err:#}");
    std::fs::remove_file(&path).ok();

    // Truncated on disk: every prefix fails, never panics.
    let (_, bundle) = trained_bundle(1);
    let bytes = bundle.to_bytes();
    let path = tmpfile("trunc");
    for frac in [1, 3, 7, 9] {
        std::fs::write(&path, &bytes[..bytes.len() * frac / 10]).unwrap();
        assert!(ModelBundle::load(&path).is_err(), "prefix {frac}0% must fail");
    }
    // Flipped format version is told apart from truncation.
    let mut patched = bytes.clone();
    patched[4..8].copy_from_slice(&7u32.to_le_bytes());
    std::fs::write(&path, &patched).unwrap();
    let err = ModelBundle::load(&path).unwrap_err();
    assert!(format!("{err:#}").contains("format version 7"), "{err:#}");
    std::fs::remove_file(&path).ok();
}

/// The acceptance pin: serving from a saved bundle skips retraining and
/// is bit-identical — window for window — to the retrain-at-startup
/// path with the same seed/config.
#[test]
fn serving_from_saved_bundle_matches_retrain_at_startup() {
    let (patient, bundle) = trained_bundle(7);

    // Save → load: the artifact that `repro serve --model` deploys.
    let path = tmpfile("serve");
    bundle.save(&path).unwrap();
    let loaded = ModelBundle::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, bundle, "the loaded artifact is the trained model, bit for bit");

    let spec = |bundle: ModelBundle| StreamSpec {
        session_id: 1,
        patient_id: 7,
        record: patient.records[1].clone(),
        bundle,
    };
    let run = |b: ModelBundle| -> StreamReport {
        Coordinator::new(SystemConfig::default(), Backend::Native)
            .run(vec![spec(b)])
            .unwrap()
    };
    let fresh = run(bundle);
    let saved = run(loaded);

    assert_eq!(fresh.sessions[0].predictions, saved.sessions[0].predictions);
    assert_eq!(fresh.sessions[0].eval.detected, saved.sessions[0].eval.detected);
    assert_eq!(fresh.sessions[0].eval.delay_s, saved.sessions[0].eval.delay_s);
    assert_eq!(fresh.sessions[0].eval.false_alarms, saved.sessions[0].eval.false_alarms);
    assert_eq!(fresh.sessions[0].model_version, saved.sessions[0].model_version);
}

/// The acceptance pin for the retrainer: the published next version
/// scores no worse than one-shot on the training windows (keep-best),
/// and versions stay monotone through the registry.
#[test]
fn online_retrain_improves_or_preserves_and_versions_monotone() {
    let (patient, bundle) = trained_bundle(3);
    let (next, report) = pipeline::retrain_bundle(
        &bundle,
        patient.train_record(),
        &pipeline::RetrainOptions::default(),
    );
    assert_eq!(next.version, 2);
    assert_eq!(next.provenance.parent_version, 1);
    assert!(
        report.best_errors <= report.initial_errors,
        "retrain must not degrade training-window accuracy \
         ({} -> {})",
        report.initial_errors,
        report.best_errors
    );
    // Measured independently with a fresh encode pass.
    let trainer = pipeline::online_trainer_for_record(
        Variant::Optimized,
        &next.config,
        patient.train_record(),
    );
    assert!(trainer.errors(&next.am) <= trainer.errors(&bundle.am));

    // Registry: v1 then v2 publish fine; re-publishing v1 afterwards is
    // rejected as stale.
    let registry = ModelRegistry::new();
    registry.publish(3, bundle.clone()).unwrap();
    registry.publish(3, next).unwrap();
    assert!(registry.publish(3, bundle).is_err());
    assert_eq!(registry.current(3).unwrap().version(), 2);
}

/// The hot-swap pin: publish v2 (class HVs swapped, so predictions
/// flip) mid-stream through the registry, and the served prediction
/// stream must equal v1's predictions up to the (deterministic) swap
/// boundary and v2's from it on — exercised under the coalescing
/// `EngineHost` with submission-order delivery, zero queue drain.
#[test]
fn mid_stream_swap_changes_results_only_at_the_boundary() {
    let (patient, v1) = trained_bundle(5);
    // v2: same encoder config, classes swapped — flips every decision.
    let mut v2 = v1.clone();
    v2.version = 2;
    v2.provenance.parent_version = 1;
    v2.am = AssociativeMemory::new(v1.am.classes[1], v1.am.classes[0]);

    let spec = |bundle: ModelBundle| StreamSpec {
        session_id: 1,
        patient_id: 5,
        record: patient.records[1].clone(),
        bundle,
    };
    let run_pure = |b: ModelBundle| -> Vec<sparse_hdc_ieeg::data::metrics::WindowPrediction> {
        Coordinator::new(SystemConfig::default(), Backend::Native)
            .run(vec![spec(b)])
            .unwrap()
            .sessions
            .remove(0)
            .predictions
    };
    let preds_v1 = run_pure(v1.clone());
    let preds_v2 = run_pure(v2.clone());
    assert_eq!(preds_v1.len(), preds_v2.len());
    assert_ne!(preds_v1, preds_v2, "class-swapped model must predict differently");

    // Swapped run: publish v2 once the first micro-batch (4 windows,
    // the SystemConfig default) has been submitted. The next batch picks
    // it up, so the boundary sits at window 4 exactly.
    let registry = Arc::new(ModelRegistry::new());
    let published = AtomicBool::new(false);
    let reg = registry.clone();
    let v2_for_hook = v2.clone();
    let coordinator = Coordinator::new(SystemConfig::default(), Backend::Native);
    let report = coordinator
        .run_with_registry(vec![spec(v1.clone())], &registry, move |windows_submitted| {
            if windows_submitted >= 4 && !published.swap(true, Ordering::Relaxed) {
                reg.publish(5, v2_for_hook.clone()).unwrap();
            }
        })
        .unwrap();

    let session = &report.sessions[0];
    assert_eq!(session.model_version, 2, "stream must end on the new version");
    assert_eq!(session.model_swaps, 1);
    assert_eq!(report.metrics.model_swaps, 1);
    assert_eq!(report.metrics.windows_failed, 0, "zero drain: nothing is lost at the swap");

    let boundary = 4usize;
    assert_eq!(
        &session.predictions[..boundary],
        &preds_v1[..boundary],
        "windows before the swap boundary must come from v1"
    );
    assert_eq!(
        &session.predictions[boundary..],
        &preds_v2[boundary..],
        "windows from the swap boundary on must come from v2"
    );
}

/// Registry sharing across sessions of one patient: both sessions see
/// the same published instance and swap together.
#[test]
fn two_sessions_of_one_patient_share_the_published_model() {
    let (patient, bundle) = trained_bundle(9);
    let specs = vec![
        StreamSpec {
            session_id: 1,
            patient_id: 9,
            record: patient.records[1].clone(),
            bundle: bundle.clone(),
        },
        StreamSpec {
            session_id: 2,
            patient_id: 9,
            record: patient.records[1].clone(),
            bundle,
        },
    ];
    let report = Coordinator::new(SystemConfig::default(), Backend::Native)
        .run(specs)
        .unwrap();
    assert_eq!(report.sessions.len(), 2);
    assert_eq!(
        report.sessions[0].predictions, report.sessions[1].predictions,
        "same patient, same record, same published model → same stream"
    );
}

/// Two *different* bundles at the same (patient, version) are ambiguous
/// — the registry slot is per patient, so serving must reject instead
/// of silently running the second session on the first session's model.
#[test]
fn conflicting_bundles_for_one_patient_are_rejected() {
    let (patient, bundle) = trained_bundle(13);
    let mut other = bundle.clone();
    other.am = AssociativeMemory::new(other.am.classes[1], other.am.classes[0]);
    let specs = vec![
        StreamSpec {
            session_id: 1,
            patient_id: 13,
            record: patient.records[1].clone(),
            bundle,
        },
        StreamSpec {
            session_id: 2,
            patient_id: 13,
            record: patient.records[1].clone(),
            bundle: other,
        },
    ];
    let err = Coordinator::new(SystemConfig::default(), Backend::Native)
        .run(specs)
        .expect_err("conflicting same-version bundles must not serve");
    let msg = format!("{err:#}");
    assert!(msg.contains("patient 13"), "{msg}");
    assert!(msg.contains("version"), "{msg}");
}
