//! Bounded plane-cache tests: serving through a budget-1
//! [`PlaneCache`] must be **window-for-window identical** to unbounded
//! serving — eviction and re-decode are memory events, never prediction
//! events — and the PR-4 mid-stream hot-swap boundary must hold exactly
//! even while eviction pressure churns the streaming patient's plane.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use sparse_hdc_ieeg::config::SystemConfig;
use sparse_hdc_ieeg::coordinator::registry::ModelRegistry;
use sparse_hdc_ieeg::coordinator::server::{Backend, Coordinator, StreamSpec, StreamReport};
use sparse_hdc_ieeg::data::synth::SynthPatient;
use sparse_hdc_ieeg::hdc::am::AssociativeMemory;
use sparse_hdc_ieeg::hdc::model::ModelBundle;
use sparse_hdc_ieeg::testkit::tiny_trained_patient;

/// Three patients, mid-stream v2 publish for patient 2 once at least 8
/// windows are in flight — the same run twice, against an unbounded and
/// a budget-`planes` registry.
fn fleet_run(cache_planes: usize) -> (StreamReport, u64, u64) {
    let fleet: Vec<(SynthPatient, ModelBundle)> =
        (1..=3u32).map(tiny_trained_patient).collect();
    let registry = Arc::new(if cache_planes == 0 {
        ModelRegistry::new()
    } else {
        ModelRegistry::with_cache_planes(cache_planes)
    });
    // v2 for patient 2: classes swapped, so a drifted boundary would
    // change predictions — the equality below is load-bearing.
    let (_, v1_p2) = &fleet[1];
    let mut v2 = v1_p2.clone();
    v2.version = 2;
    v2.provenance.parent_version = 1;
    v2.am = AssociativeMemory::new(v1_p2.am.classes[1], v1_p2.am.classes[0]);

    let streams: Vec<StreamSpec> = fleet
        .iter()
        .enumerate()
        .map(|(i, (patient, bundle))| StreamSpec {
            session_id: i as u64 + 1,
            patient_id: i as u32 + 1,
            record: patient.records[1].clone(),
            bundle: bundle.clone(),
        })
        .collect();

    let published = AtomicBool::new(false);
    let reg = registry.clone();
    let coordinator = Coordinator::new(SystemConfig::default(), Backend::Native);
    let report = coordinator
        .run_with_registry(streams, &registry, move |windows_submitted| {
            if windows_submitted >= 8 && !published.swap(true, Ordering::Relaxed) {
                reg.publish(2, v2.clone()).unwrap();
            }
        })
        .unwrap();
    let stats = registry.plane_cache().stats();
    (report, stats.evictions, stats.redecodes)
}

/// The acceptance pin: `cache_planes = 1` over three patients with a
/// mid-stream publish serves the exact windows (index, label, margin)
/// and ends on the exact model versions the unbounded registry serves,
/// while actually evicting and re-decoding along the way.
#[test]
fn budget_one_cache_is_window_for_window_identical_to_unbounded() {
    let (unbounded, ev0, _) = fleet_run(0);
    let (bounded, evictions, redecodes) = fleet_run(1);

    assert_eq!(ev0, 0, "unbounded cache must never evict");
    assert!(
        evictions > 0,
        "three patients round-robin through one slot must evict"
    );
    assert!(redecodes > 0, "evicted planes must be decoded again on re-touch");

    assert_eq!(unbounded.sessions.len(), bounded.sessions.len());
    for (u, b) in unbounded.sessions.iter().zip(&bounded.sessions) {
        assert_eq!(u.session_id, b.session_id);
        assert_eq!(u.model_version, b.model_version, "session {}", u.session_id);
        assert_eq!(u.model_swaps, b.model_swaps, "session {}", u.session_id);
        assert_eq!(
            u.predictions, b.predictions,
            "session {}: eviction must never change a window",
            u.session_id
        );
    }
    // The mid-stream publish really happened: patient 2 ends on v2.
    assert_eq!(bounded.sessions[1].model_version, 2);
    assert!(bounded.sessions[1].model_swaps >= 1);
    assert_eq!(bounded.metrics.plane_evictions, evictions);
    assert!(bounded.metrics.plane_redecodes > 0);
    assert_eq!(unbounded.metrics.plane_evictions, 0);
}

/// The PR-4 hot-swap pin under eviction pressure: a budget-1 registry
/// also holds two idle patients whose planes the tick hook touches every
/// chunk, so the streaming patient's plane is evicted between batches —
/// and the v1→v2 boundary must still land at window 4 exactly.
#[test]
fn swap_boundary_holds_under_eviction_pressure() {
    let (patient, v1) = tiny_trained_patient(5);
    let mut v2 = v1.clone();
    v2.version = 2;
    v2.provenance.parent_version = 1;
    v2.am = AssociativeMemory::new(v1.am.classes[1], v1.am.classes[0]);

    let spec = |bundle: ModelBundle| StreamSpec {
        session_id: 1,
        patient_id: 5,
        record: patient.records[1].clone(),
        bundle,
    };
    let run_pure = |b: ModelBundle| -> Vec<sparse_hdc_ieeg::data::metrics::WindowPrediction> {
        Coordinator::new(SystemConfig::default(), Backend::Native)
            .run(vec![spec(b)])
            .unwrap()
            .sessions
            .remove(0)
            .predictions
    };
    let preds_v1 = run_pure(v1.clone());
    let preds_v2 = run_pure(v2.clone());
    assert_ne!(preds_v1, preds_v2, "class-swapped model must predict differently");

    let registry = Arc::new(ModelRegistry::with_cache_planes(1));
    // Two idle neighbours share the single slot with the streaming
    // patient; touching them from the hook evicts patient 5's plane.
    let (_, idle6) = tiny_trained_patient(6);
    let (_, idle7) = tiny_trained_patient(7);
    registry.publish(6, idle6).unwrap();
    registry.publish(7, idle7).unwrap();

    let published = AtomicBool::new(false);
    let reg = registry.clone();
    let coordinator = Coordinator::new(SystemConfig::default(), Backend::Native);
    let report = coordinator
        .run_with_registry(vec![spec(v1.clone())], &registry, move |windows_submitted| {
            // Evict patient 5 between every routed chunk…
            reg.current(6).unwrap().plane();
            reg.current(7).unwrap().plane();
            // …and publish v2 after the first micro-batch (4 windows,
            // the SystemConfig default), exactly as the PR-4 pin does.
            if windows_submitted >= 4 && !published.swap(true, Ordering::Relaxed) {
                reg.publish(5, v2.clone()).unwrap();
            }
        })
        .unwrap();

    let stats = registry.plane_cache().stats();
    assert!(stats.evictions > 0, "the hook must thrash the single slot");
    assert!(stats.redecodes > 0);
    assert!(registry.plane_cache().resident() <= 1);

    let session = &report.sessions[0];
    assert_eq!(session.model_version, 2, "stream must end on the new version");
    assert_eq!(session.model_swaps, 1);
    assert_eq!(report.metrics.windows_failed, 0, "zero drain at the swap");
    let boundary = 4usize;
    assert_eq!(
        &session.predictions[..boundary],
        &preds_v1[..boundary],
        "pre-boundary windows must come from v1 despite eviction churn"
    );
    assert_eq!(
        &session.predictions[boundary..],
        &preds_v2[boundary..],
        "post-boundary windows must come from v2 despite eviction churn"
    );
}
