//! Property-based tests (testkit runner — proptest substitute, see
//! DESIGN.md §2) over the HDC algebra, the encoder pipelines, the
//! hardware-model invariants and the coordinator.
//!
//! Reproduce a failing case with `HDC_PROPTEST_SEED=<seed> cargo test`.

use sparse_hdc_ieeg::coordinator::detector::Detector;
use sparse_hdc_ieeg::data::metrics::{evaluate_record, AlarmPolicy, WindowPrediction};
use sparse_hdc_ieeg::data::synth::{Record, Seizure};
use sparse_hdc_ieeg::hdc::am::AssociativeMemory;
use sparse_hdc_ieeg::hdc::bundling;
use sparse_hdc_ieeg::hdc::classifier::{ClassifierConfig, Encoder, SparseEncoder, Variant};
use sparse_hdc_ieeg::hdc::compim::{pack, unpack};
use sparse_hdc_ieeg::hdc::hv::Hv;
use sparse_hdc_ieeg::hdc::sparse::{bind_bitdomain, SparseHv};
use sparse_hdc_ieeg::hdc::temporal::{threshold_for_max_density, TemporalAccumulator};
use sparse_hdc_ieeg::params::{CHANNELS, FRAMES_PER_PREDICTION, SAMPLE_RATE_HZ, SEGMENTS};
use sparse_hdc_ieeg::testkit::{property, Gen};

// ---------------------------------------------------------------------
// HDC algebra
// ---------------------------------------------------------------------

#[test]
fn prop_bind_unbind_roundtrip() {
    property("bind-unbind = id", 300, |g: &mut Gen| {
        let a = g.sparse_hv();
        let b = g.sparse_hv();
        assert_eq!(a.bind(&b).unbind(&b), a);
        assert_eq!(a.unbind(&b).bind(&b), a);
    });
}

#[test]
fn prop_bind_commutative_and_associative() {
    property("bind commutes/associates (position adds)", 300, |g| {
        let a = g.sparse_hv();
        let b = g.sparse_hv();
        let c = g.sparse_hv();
        assert_eq!(a.bind(&b), b.bind(&a));
        assert_eq!(a.bind(&b).bind(&c), a.bind(&c).bind(&b));
    });
}

#[test]
fn prop_position_vs_bit_domain_binding() {
    property("CompIM bind == decode+shift bind", 300, |g| {
        let e = g.sparse_hv();
        let d = g.sparse_hv();
        let pos = e.bind(&d).to_hv();
        let bits = bind_bitdomain(&e.to_hv(), &d.to_hv()).unwrap();
        assert_eq!(pos, bits);
    });
}

#[test]
fn prop_pack_unpack_identity() {
    property("CompIM 56-bit packing is lossless", 300, |g| {
        let s = g.sparse_hv();
        let w = pack(&s);
        assert_eq!(w >> 56, 0);
        assert_eq!(unpack(w), s);
    });
}

#[test]
fn prop_overlap_symmetric_and_bounded() {
    property("overlap symmetric, <= min popcount", 200, |g| {
        let da = g.f64() * 0.5 + 0.01;
        let db = g.f64() * 0.5 + 0.01;
        let a = g.hv(da);
        let b = g.hv(db);
        assert_eq!(a.overlap(&b), b.overlap(&a));
        assert!(a.overlap(&b) <= a.popcount().min(b.popcount()));
        assert_eq!(a.overlap(&a), a.popcount());
    });
}

#[test]
fn prop_hamming_triangle_inequality() {
    property("hamming is a metric", 100, |g| {
        let a = g.hv_half();
        let b = g.hv_half();
        let c = g.hv_half();
        assert!(a.hamming(&c) <= a.hamming(&b) + b.hamming(&c));
        assert_eq!(a.hamming(&b), b.hamming(&a));
        assert_eq!(a.hamming(&a), 0);
    });
}

// ---------------------------------------------------------------------
// Bundling / temporal invariants
// ---------------------------------------------------------------------

#[test]
fn prop_or_bundle_is_union_and_monotone() {
    property("OR bundle = union; more inputs never lose bits", 150, |g| {
        let n = g.range(1, CHANNELS);
        let hvs: Vec<SparseHv> = g.vec(n, |g| g.sparse_hv());
        let bits: Vec<Hv> = hvs.iter().map(|h| h.to_hv()).collect();
        let bundled = bundling::bundle_or_pos(&hvs);
        assert_eq!(bundled, bundling::bundle_or(&bits));
        for hv in &bits {
            assert_eq!(hv.and(&bundled), *hv);
        }
        let more = bundling::bundle_or_pos(&{
            let mut v = hvs.clone();
            v.push(g.sparse_hv());
            v
        });
        assert_eq!(bundled.and(&more), bundled);
    });
}

#[test]
fn prop_thinning_monotone_in_threshold() {
    property("higher threshold subset of lower threshold", 150, |g| {
        let n = g.range(2, CHANNELS);
        let hvs: Vec<Hv> = g.vec(n, |g| g.sparse_hv().to_hv());
        let counts = bundling::element_counts(&hvs);
        let t = g.range(1, n - 1) as u16;
        let lo = bundling::thin(&counts, t);
        let hi = bundling::thin(&counts, t + 1);
        assert_eq!(hi.and(&lo), hi, "threshold {t}");
        assert!(hi.popcount() <= lo.popcount());
    });
}

#[test]
fn prop_temporal_threshold_tuner_is_minimal() {
    property("threshold_for_max_density minimal & respects bound", 60, |g| {
        let mut acc = TemporalAccumulator::new();
        let frames = g.range(10, FRAMES_PER_PREDICTION);
        for _ in 0..frames {
            let d = g.f64() * 0.5;
            acc.add(&g.hv(d));
        }
        let max_d = 0.05 + g.f64() * 0.45;
        let t = threshold_for_max_density(&acc.counts(), max_d);
        assert!(acc.peek(t).density() <= max_d + 1e-12);
        if t > 1 {
            assert!(acc.peek(t - 1).density() > max_d);
        }
    });
}

#[test]
fn prop_encoder_deterministic_and_reset_safe() {
    property("same frames -> same query; reset forgets", 8, |g| {
        let cfg = ClassifierConfig::optimized();
        let frames = g.frames(FRAMES_PER_PREDICTION);
        let run = |frames: &[[u8; CHANNELS]]| {
            let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
            let mut q = None;
            for f in frames {
                q = q.or(enc.push_frame(f));
            }
            q.unwrap()
        };
        let q1 = run(&frames);
        let q2 = run(&frames);
        assert_eq!(q1, q2);

        let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
        for f in frames.iter().take(g.range(1, 200)) {
            enc.push_frame(f);
        }
        enc.reset();
        let mut q3 = None;
        for f in &frames {
            q3 = q3.or(enc.push_frame(f));
        }
        assert_eq!(q3.unwrap(), q1);
    });
}

#[test]
fn prop_sparse_variants_equivalent_at_threshold_one() {
    property("3 sparse designs are one function (spatial_threshold=1)", 4, |g| {
        let cfg = ClassifierConfig {
            spatial_threshold: 1,
            ..ClassifierConfig::optimized()
        };
        let frames = g.frames(FRAMES_PER_PREDICTION);
        let mut queries = Vec::new();
        for v in [Variant::SparseBaseline, Variant::SparseCompIm, Variant::Optimized] {
            let mut enc = SparseEncoder::new(v, cfg.clone());
            let mut q = None;
            for f in &frames {
                q = q.or(enc.push_frame(f));
            }
            queries.push(q.unwrap());
        }
        assert_eq!(queries[0], queries[1]);
        assert_eq!(queries[1], queries[2]);
    });
}

// ---------------------------------------------------------------------
// AM / metrics / detector invariants
// ---------------------------------------------------------------------

#[test]
fn prop_am_search_picks_argmax() {
    property("AM search returns argmax with interictal ties", 200, |g| {
        let am = AssociativeMemory::new(g.hv(0.3), g.hv(0.3));
        let q = g.hv(0.25);
        let r = am.search(&q);
        let s0 = q.overlap(&am.classes[0]);
        let s1 = q.overlap(&am.classes[1]);
        assert_eq!(r.scores, [s0, s1]);
        assert_eq!(r.is_ictal(), s1 > s0);
    });
}

#[test]
fn prop_detector_never_fires_without_k_run() {
    property("K-consecutive detector correctness", 100, |g| {
        let k = g.range(1, 4);
        let mut det = Detector::new(k);
        let n = g.range(10, 60);
        let decisions: Vec<bool> = g.vec(n, |g| g.bool(0.4));
        let mut run = 0usize;
        let mut latched = false;
        for (i, &ictal) in decisions.iter().enumerate() {
            let fired = det.push(i as u64, ictal, 1).is_some();
            if ictal {
                run += 1;
            } else {
                run = 0;
                latched = false;
            }
            let should_fire = ictal && run == k && !latched;
            if fired {
                latched = true;
            }
            assert_eq!(fired, should_fire, "step {i} (k={k})");
        }
    });
}

#[test]
fn prop_detection_delay_nonnegative_and_window_quantized() {
    property("delay >= 0 and a multiple of the window period", 100, |g| {
        let windows = g.range(6, 24);
        let onset_w = g.range(1, windows - 2);
        let record = Record {
            samples: vec![0f32; windows * FRAMES_PER_PREDICTION * CHANNELS],
            seizure: Some(Seizure {
                onset: onset_w * FRAMES_PER_PREDICTION,
                offset: (onset_w + 2) * FRAMES_PER_PREDICTION,
            }),
            fs: SAMPLE_RATE_HZ,
        };
        let preds: Vec<WindowPrediction> = (0..windows)
            .map(|idx| WindowPrediction {
                idx,
                is_ictal: g.bool(0.3) || idx == onset_w + 1,
                margin: 0,
            })
            .collect();
        let out = evaluate_record(&record, &preds, AlarmPolicy::default(), 10.0);
        if let Some(d) = out.delay_s {
            assert!(d >= 0.0);
            let w = FRAMES_PER_PREDICTION as f64 / SAMPLE_RATE_HZ;
            let ratio = d / w;
            assert!((ratio - ratio.round()).abs() < 1e-9, "delay {d} not quantized");
        }
    });
}

// ---------------------------------------------------------------------
// Hardware-model / encoder invariants
// ---------------------------------------------------------------------

#[test]
fn prop_hwmodel_stimulus_length_stable() {
    use sparse_hdc_ieeg::hwmodel::designs::{analyze, patient11_stimulus};
    let cfg = ClassifierConfig {
        spatial_threshold: 1,
        ..ClassifierConfig::optimized()
    };
    let short = analyze(Variant::Optimized, &cfg, &patient11_stimulus(1));
    let long = analyze(Variant::Optimized, &cfg, &patient11_stimulus(3));
    assert_eq!(short.area_mm2(), long.area_mm2());
    let e_s = short.energy_nj_per_pred();
    let e_l = long.energy_nj_per_pred();
    assert!(
        (e_s - e_l).abs() / e_l < 0.25,
        "per-prediction energy unstable: {e_s} vs {e_l}"
    );
}

#[test]
fn prop_bound_hv_always_sparse() {
    property("binding preserves one 1-bit per segment", 200, |g| {
        let e = g.sparse_hv();
        let d = g.sparse_hv();
        let hv = e.bind(&d).to_hv();
        assert_eq!(hv.popcount(), SEGMENTS as u32);
        for s in 0..SEGMENTS {
            let seg = hv.segment(s);
            assert_eq!(seg[0].count_ones() + seg[1].count_ones(), 1);
        }
    });
}

#[test]
fn prop_spatial_density_bounded_query_monotone() {
    // The 50% bound (paper §III-B) applies to the *spatial* bundling (64
    // HVs × 8 ones / 1024 elements); the temporal union can exceed it —
    // which is exactly why the temporal thinning threshold exists. The
    // query must instead be monotone in the threshold and ⊆ the union.
    property("spatial <= 50%; query monotone in threshold", 4, |g| {
        let cfg = ClassifierConfig::optimized();
        let mut enc = SparseEncoder::new(Variant::Optimized, cfg.clone());
        let frames = g.frames(FRAMES_PER_PREDICTION);
        for f in frames.iter().take(16) {
            assert!(enc.spatial_encode(f).density() <= 0.5 + 1e-12);
        }
        let run = |thr: u16| {
            let mut enc = SparseEncoder::new(
                Variant::Optimized,
                ClassifierConfig {
                    temporal_threshold: thr,
                    ..cfg.clone()
                },
            );
            let mut q = None;
            for f in &frames {
                q = q.or(enc.push_frame(f));
            }
            q.unwrap()
        };
        let t = g.range(1, 254) as u16;
        let lo = run(t);
        let hi = run(t + 1);
        assert_eq!(hi.and(&lo), hi, "threshold {t}: higher must be subset");
        // Paper's operating point keeps the query in the 20–30% band on
        // patient data; on arbitrary random codes we only check ≤ union.
        let union = run(1);
        assert_eq!(lo.and(&union), lo);
    });
}

#[test]
fn prop_false_alarm_rate_matches_naive_oracle() {
    use sparse_hdc_ieeg::coordinator::metrics::FalseAlarmRate;
    // The O(1) sliding ring vs recompute-from-scratch over the retained
    // outcome Vec, through randomized push/clear sequences that cross
    // the full() boundary and wrap the ring several times over.
    property("FalseAlarmRate ring == naive tail recount", 200, |g| {
        let capacity = g.range(1, 9);
        let mut est = FalseAlarmRate::new(capacity);
        let mut oracle: Vec<bool> = Vec::new();
        let ops = g.range(1, 4 * capacity + 20);
        for i in 0..ops {
            if g.bool(0.1) {
                est.clear();
                oracle.clear();
            } else {
                let fa = g.bool(0.4);
                est.push(fa);
                oracle.push(fa);
            }
            let start = oracle.len().saturating_sub(capacity);
            let tail = &oracle[start..];
            let hits = tail.iter().filter(|&&b| b).count();
            assert_eq!(est.len(), tail.len(), "len after op {i} (cap {capacity})");
            assert_eq!(est.false_alarms(), hits, "hits after op {i} (cap {capacity})");
            assert_eq!(est.full(), tail.len() == capacity, "full after op {i}");
            assert_eq!(est.capacity(), capacity);
            let expect = if tail.is_empty() {
                0.0
            } else {
                hits as f64 / tail.len() as f64
            };
            assert!((est.rate() - expect).abs() < 1e-12, "rate after op {i}");
        }
    });
}

#[test]
fn prop_hv_bitops_identities() {
    property("boolean algebra on HVs", 200, |g| {
        let a = g.hv_half();
        let b = g.hv_half();
        assert_eq!(a.xor(&b).xor(&b), a);
        assert_eq!(a.and(&b).or(&a), a); // absorption
        assert_eq!(
            a.or(&b).popcount() + a.and(&b).popcount(),
            a.popcount() + b.popcount()
        );
        assert_eq!(a.hamming(&b), a.or(&b).popcount() - a.and(&b).popcount());
    });
}
