//! Retrain-scheduler determinism tests: a planted false-alarm burst
//! triggers exactly one retrain at a pinned window index, and the
//! incremental (counter-plane) retrain scores bit-identically to a
//! from-record retrain with the same epochs/seed.

use std::collections::BTreeMap;
use std::sync::Arc;

use sparse_hdc_ieeg::coordinator::registry::ModelRegistry;
use sparse_hdc_ieeg::coordinator::scheduler::{PatientWatch, RetrainPolicy, RetrainScheduler};
use sparse_hdc_ieeg::hdc::classifier::Variant;
use sparse_hdc_ieeg::pipeline::{self, RetrainOptions};
use sparse_hdc_ieeg::testkit::{planted_false_alarm_stream, tiny_trained_patient};

/// The satellite pin: a clean stream with one planted burst fires the
/// policy exactly once, at an index derivable by hand. Policy: 25% over
/// a 16-window estimator → the 4th burst window crosses (4/16 = 25%),
/// so the trigger index is `burst_start + 4` (1-based outcome count).
#[test]
fn planted_burst_triggers_once_at_the_pinned_index() {
    let policy = RetrainPolicy {
        epochs: 2,
        fa_window: 16,
        fa_rate: 0.25,
        cooldown: 10_000,
        max_retrains: 1,
    };
    let burst_start = 120usize; // 0-based window index where the burst begins
    let stream = planted_false_alarm_stream(300, burst_start, 12);

    let mut watch = PatientWatch::new(&policy);
    let mut triggers = Vec::new();
    for (idx, &fa) in stream.iter().enumerate() {
        if watch.observe(&policy, fa) {
            triggers.push(idx);
        }
    }
    // 0-based: the burst's 4th window sits at burst_start + 3.
    assert_eq!(triggers, vec![burst_start + 3], "exactly one trigger, pinned");
    assert_eq!(watch.retrains, 1);
    assert_eq!(watch.windows_seen, 300);
}

/// The same stream through the full scheduler front-end (per-patient
/// watch map + trigger log) — the log records the identical index, and
/// an independent patient's clean stream stays untriggered.
#[test]
fn scheduler_trigger_log_matches_the_pure_watch() {
    let policy = RetrainPolicy {
        epochs: 2,
        fa_window: 16,
        fa_rate: 0.25,
        cooldown: 10_000,
        max_retrains: 1,
    };
    // No training records: triggers are logged, retrains report-skip.
    let scheduler = RetrainScheduler::new(
        policy,
        Arc::new(ModelRegistry::new()),
        None,
        BTreeMap::new(),
    )
    .foreground();

    let stream = planted_false_alarm_stream(300, 120, 12);
    for &fa in &stream {
        scheduler.observe(1, fa); // bursty patient
        scheduler.observe(2, false); // clean patient
    }
    // 1-based window count: 120 clean + 4 burst windows = 124.
    assert_eq!(scheduler.triggers(), vec![(1, 124)]);
    assert_eq!(scheduler.retrains(1), 1);
    assert_eq!(scheduler.retrains(2), 0);
}

/// The other satellite pin: resuming from a one-shot bundle's persisted
/// counter planes is **bit-identical** to re-seeding from the record —
/// same AM planes, same epoch trajectory, same persisted counters —
/// because the stored planes *are* the from-record seeding state.
#[test]
fn incremental_retrain_bit_identical_to_from_record() {
    let (patient, bundle) = tiny_trained_patient(17);
    assert!(bundle.counters.is_some(), "one-shot training persists its planes");
    let record = patient.train_record();

    for epochs in [1usize, 4, 8] {
        let opts = RetrainOptions {
            max_epochs: epochs,
            ..Default::default()
        };
        // Incremental: the counter path (bundle carries planes).
        let (inc, inc_report) = pipeline::retrain_bundle(&bundle, record, &opts);
        // From-record: force the fallback by stripping the planes.
        let mut stripped = bundle.clone();
        stripped.counters = None;
        let (full, full_report) = pipeline::retrain_bundle(&stripped, record, &opts);

        assert_eq!(inc.am.classes, full.am.classes, "epochs {epochs}: AM must be bit-identical");
        assert_eq!(inc.version, full.version);
        assert_eq!(inc.config, full.config);
        assert_eq!(
            inc.counters, full.counters,
            "epochs {epochs}: persisted post-retrain planes must agree"
        );
        assert_eq!(inc_report.initial_errors, full_report.initial_errors);
        assert_eq!(inc_report.best_errors, full_report.best_errors);
        assert_eq!(inc_report.epochs.len(), full_report.epochs.len());
        assert_eq!(inc.provenance.train_windows, full.provenance.train_windows);
    }
}

/// A threshold re-tune invalidates the stored planes: the retrain must
/// fall back to from-record seeding (different encoding ⇒ the planes
/// cannot be reused), and the result equals the stripped-bundle path.
#[test]
fn retune_falls_back_to_from_record_seeding() {
    let (patient, bundle) = tiny_trained_patient(19);
    let record = patient.train_record();
    let opts = RetrainOptions {
        max_epochs: 2,
        max_density: Some(0.10),
        ..Default::default()
    };
    let (with_planes, _) = pipeline::retrain_bundle(&bundle, record, &opts);
    let mut stripped = bundle.clone();
    stripped.counters = None;
    let (without_planes, _) = pipeline::retrain_bundle(&stripped, record, &opts);
    assert_eq!(with_planes.am.classes, without_planes.am.classes);
    assert_eq!(with_planes.config, without_planes.config);
    assert_ne!(
        with_planes.config.temporal_threshold, bundle.config.temporal_threshold,
        "the 10%-density re-tune must actually move the threshold for this pin to bite"
    );
}

/// Chained incremental retrains genuinely accumulate: every retrained
/// bundle's persisted planes thin to exactly its published AM, so v3
/// resumed from v2's planes starts from the state actually serving —
/// not from v1's one-shot seeding.
#[test]
fn chained_retrains_resume_from_the_published_state() {
    let (patient, v1) = tiny_trained_patient(23);
    let record = patient.train_record();
    let opts = RetrainOptions {
        max_epochs: 4,
        ..Default::default()
    };
    let (v2, _) = pipeline::retrain_bundle(&v1, record, &opts);
    assert_eq!(v2.version, 2);
    // Self-consistency: the persisted planes ARE the published model.
    let resumed_am = sparse_hdc_ieeg::hdc::online::OnlineTrainer::from_counters(
        Variant::Optimized,
        v2.config.train_density,
        v2.counters.as_ref().unwrap(),
    )
    .build_am();
    assert_eq!(resumed_am.classes, v2.am.classes, "planes thin to the published AM");

    let (v3, v3_report) = pipeline::retrain_bundle(&v2, record, &opts);
    assert_eq!(v3.version, 3);
    assert_eq!(v3.provenance.parent_version, 2);
    // Keep-best across the chain: v3 never scores worse than v2 on the
    // training windows it resumed from.
    assert!(v3_report.best_errors <= v3_report.initial_errors);
}
