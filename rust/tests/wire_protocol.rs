//! Adversarial wire-codec suite: the decoder is total. Every mutation
//! of every frame type — any byte flipped, any truncation point, any
//! chunking of the stream — must produce `Err` or a valid frame, never
//! a panic, and never an allocation sized by untrusted bytes (the
//! oversize-header tests in the unit suite pin that; here we sweep).

use sparse_hdc_ieeg::params::CHANNELS;
use sparse_hdc_ieeg::testkit::{property, wire_frame, Gen, TrickleReader};
use sparse_hdc_ieeg::transport::frame::{
    Frame, FrameDecoder, FrameReader, PatientStatus, ReadOutcome, HEADER_LEN, MAX_PAYLOAD,
};

/// One representative of every frame kind, with non-trivial payloads.
fn exemplars() -> Vec<Frame> {
    vec![
        Frame::Subscribe { patient: 0xDEAD_BEEF },
        Frame::Samples {
            seq: u64::MAX,
            samples: (0..3 * CHANNELS).map(|i| i as f32 * 0.5 - 7.0).collect(),
        },
        Frame::Samples {
            seq: 0,
            samples: Vec::new(),
        },
        Frame::Prediction {
            window: 1 << 40,
            is_ictal: true,
            margin: i64::MIN,
            model_version: 3,
        },
        Frame::Heartbeat { seq: 0 },
        Frame::Shutdown {
            reason: "π: stale after 5 s".to_string(),
        },
        Frame::Shutdown {
            reason: String::new(),
        },
        Frame::ShardHello {
            shard: 3,
            epoch: u64::MAX,
        },
        Frame::Lease {
            patient: 0xDEAD_BEEF,
            shard: 1,
            epoch: 42,
        },
        Frame::Route {
            patient: 9,
            shard: 0,
            addr: "127.0.0.1:7001".to_string(),
        },
        Frame::Route {
            patient: 9,
            shard: 0,
            addr: String::new(),
        },
        Frame::Status,
        Frame::StatusReport {
            cache_hits: u64::MAX,
            cache_misses: 1,
            cache_evictions: 0,
            cache_redecodes: 7,
            patients: vec![
                PatientStatus {
                    patient: 2,
                    fa_hits: 3,
                    fa_seen: 48,
                    retrains: 1,
                    triggers: 2,
                    feedback_depth: 48,
                },
                PatientStatus {
                    patient: 0xDEAD_BEEF,
                    fa_hits: 0,
                    fa_seen: 0,
                    retrains: 0,
                    triggers: 0,
                    feedback_depth: 0,
                },
            ],
        },
        Frame::StatusReport {
            cache_hits: 0,
            cache_misses: 0,
            cache_evictions: 0,
            cache_redecodes: 0,
            patients: Vec::new(),
        },
    ]
}

/// Drain a decoder fed `bytes` all at once: every yielded frame must be
/// valid (the decoder said so); the call must simply never panic.
fn drain(bytes: &[u8]) -> (usize, bool) {
    let mut d = FrameDecoder::new();
    d.extend(bytes);
    let mut frames = 0;
    loop {
        match d.next_frame() {
            Ok(Some(_)) => frames += 1,
            Ok(None) => return (frames, false),
            Err(_) => return (frames, true),
        }
    }
}

#[test]
fn every_byte_flip_of_every_frame_is_err_or_valid() {
    for frame in exemplars() {
        let clean = frame.to_bytes();
        for offset in 0..clean.len() {
            for bit in 0..8 {
                let mut bytes = clean.clone();
                bytes[offset] ^= 1 << bit;
                // Err, a (different-but-valid) frame, or a partial wait
                // are all acceptable outcomes; the property is that
                // decoding terminates without panicking. A flip in the
                // 4-byte length field can at most make the decoder wait
                // for bytes that never come — never decode garbage as a
                // longer frame, which the (frames ≤ 1) bound pins.
                let (frames, _errored) = drain(&bytes);
                assert!(
                    frames <= 1,
                    "{} with offset {offset} bit {bit} flipped decoded {frames} frames",
                    frame.kind_name()
                );
            }
        }
    }
}

#[test]
fn every_truncation_of_every_frame_never_yields_the_frame() {
    for frame in exemplars() {
        let clean = frame.to_bytes();
        for cut in 0..clean.len() {
            let mut d = FrameDecoder::new();
            d.extend(&clean[..cut]);
            match d.next_frame() {
                // A truncated single frame can never decode to Some —
                // the payload length in the header is exact.
                Ok(Some(f)) => panic!(
                    "{} truncated to {cut}/{} bytes decoded as {}",
                    frame.kind_name(),
                    clean.len(),
                    f.kind_name()
                ),
                Ok(None) | Err(_) => {}
            }
            // An EOF at that point must be reported as truncation by
            // the stream reader (except cut == 0: an empty stream is an
            // orderly EOF).
            let mut r = FrameReader::new(std::io::Cursor::new(clean[..cut].to_vec()));
            match r.read() {
                Ok(ReadOutcome::Eof) => assert_eq!(cut, 0, "mid-frame EOF must error"),
                Ok(ReadOutcome::Frame(_)) => panic!("truncated stream yielded a frame"),
                Ok(ReadOutcome::Idle) => panic!("Cursor never times out"),
                Err(_) => assert!(cut > 0),
            }
        }
    }
}

#[test]
fn flipped_length_bytes_never_oversize_the_buffer() {
    // Corrupt each length byte to its max: claimed payloads past the cap
    // must be rejected from the header alone, without buffering them.
    for frame in exemplars() {
        let clean = frame.to_bytes();
        for len_byte in 6..HEADER_LEN {
            let mut bytes = clean.clone();
            bytes[len_byte] = 0xFF;
            let claimed =
                u32::from_le_bytes([bytes[6], bytes[7], bytes[8], bytes[9]]) as usize;
            let mut d = FrameDecoder::new();
            d.extend(&bytes);
            match d.next_frame() {
                Err(_) => assert!(
                    claimed > MAX_PAYLOAD,
                    "{}: in-cap length {claimed} should wait for bytes, not error",
                    frame.kind_name()
                ),
                Ok(None) => {
                    assert!(claimed <= MAX_PAYLOAD);
                    // Waiting is fine, but only for an in-cap claim, and
                    // the decoder must not have grown to hold it.
                    assert!(d.buffered() <= bytes.len());
                }
                Ok(Some(_)) => panic!("corrupt length decoded a frame"),
            }
        }
    }
}

/// The model-lifecycle fuzz idiom (several seeded flips per offset,
/// Err-or-valid, an accepted frame must round-trip), applied to the new
/// telemetry frames specifically: their payloads carry semantic
/// invariants (fa_hits ≤ fa_seen, strictly ascending patients) that the
/// generic sweep above only exercises with one flip per bit.
#[test]
fn status_frames_survive_multi_flip_fuzz_and_reject_trailing_bytes() {
    use sparse_hdc_ieeg::rng::Xoshiro256;
    let frames: Vec<Frame> = exemplars()
        .into_iter()
        .filter(|f| matches!(f, Frame::Status | Frame::StatusReport { .. }))
        .collect();
    assert_eq!(frames.len(), 3, "both telemetry kinds must be in the exemplars");
    let mut rng = Xoshiro256::new(0x57A7_0510);
    for frame in &frames {
        let clean = frame.to_bytes();
        let mut survived = 0usize;
        for offset in 0..clean.len() {
            for _ in 0..4 {
                let mask = (rng.next_below(255) + 1) as u8;
                let mut bytes = clean.clone();
                bytes[offset] ^= mask;
                let mut d = FrameDecoder::new();
                d.extend(&bytes);
                match d.next_frame() {
                    Ok(Some(f)) => {
                        survived += 1;
                        // An accepted mutant is a real frame: it must
                        // re-encode and re-decode to itself.
                        let mut d2 = FrameDecoder::new();
                        d2.extend(&f.to_bytes());
                        assert_eq!(d2.next_frame().unwrap(), Some(f));
                    }
                    Ok(None) | Err(_) => {}
                }
            }
        }
        // Flips in the cache counters / fa payload values must survive
        // as valid (different) frames — an all-rejecting decoder would
        // also pass the panic check. Status has no payload to mutate
        // into validity, so only reports assert survivors.
        if matches!(frame, Frame::StatusReport { patients, .. } if !patients.is_empty()) {
            assert!(survived > 0, "no flip of a StatusReport ever stayed valid");
        }
    }

    // Trailing payload bytes: grow the payload by one garbage byte and
    // patch the header length to cover it — total decode must reject the
    // slack, not silently ignore it.
    for frame in &frames {
        let mut bytes = frame.to_bytes();
        bytes.push(0xAA);
        let len = (bytes.len() - HEADER_LEN) as u32;
        bytes[6..10].copy_from_slice(&len.to_le_bytes());
        let mut d = FrameDecoder::new();
        d.extend(&bytes);
        assert!(
            d.next_frame().is_err(),
            "{} accepted a trailing payload byte",
            frame.kind_name()
        );
    }
}

#[test]
fn random_frame_streams_round_trip_through_any_chunking() {
    property("wire/roundtrip-trickle", 200, |g: &mut Gen| {
        let frames: Vec<Frame> = (0..g.range(1, 8)).map(|_| wire_frame(g)).collect();
        let stream: Vec<u8> = frames.iter().flat_map(|f| f.to_bytes()).collect();
        let trickle = TrickleReader::new(
            std::io::Cursor::new(stream),
            g.u64(),
            g.range(1, 17),
        );
        let mut reader = FrameReader::new(trickle);
        let mut got = Vec::new();
        loop {
            match reader.read().expect("clean stream") {
                ReadOutcome::Frame(f) => got.push(f),
                ReadOutcome::Eof => break,
                ReadOutcome::Idle => unreachable!("Cursor never times out"),
            }
        }
        assert_eq!(got, frames);
    });
}

#[test]
fn random_corruption_of_random_streams_never_panics() {
    property("wire/corruption-fuzz", 300, |g: &mut Gen| {
        let frames: Vec<Frame> = (0..g.range(1, 5)).map(|_| wire_frame(g)).collect();
        let mut stream: Vec<u8> = frames.iter().flat_map(|f| f.to_bytes()).collect();
        for _ in 0..g.range(1, 4) {
            let i = g.usize_below(stream.len());
            stream[i] ^= 1 << g.usize_below(8);
        }
        // Feed in random chunks; count frames out. Valid-or-Err is all
        // we require — corruption may land in payload bytes the codec
        // legitimately cannot distinguish from data.
        let mut d = FrameDecoder::new();
        let mut rest: &[u8] = &stream;
        let mut out = 0usize;
        while !rest.is_empty() {
            let n = 1 + g.usize_below(rest.len().min(16));
            d.extend(&rest[..n]);
            rest = &rest[n..];
            loop {
                match d.next_frame() {
                    Ok(Some(_)) => out += 1,
                    Ok(None) => break,
                    Err(_) => return, // framing lost: connection would close
                }
            }
        }
        assert!(out <= frames.len(), "corruption cannot mint extra frames");
    });
}

#[test]
#[ignore = "exhaustive all-offsets x all-bits sweep over random streams; run with --ignored"]
fn exhaustive_corruption_sweep() {
    property("wire/corruption-exhaustive", 40, |g: &mut Gen| {
        let frames: Vec<Frame> = (0..g.range(1, 4)).map(|_| wire_frame(g)).collect();
        let clean: Vec<u8> = frames.iter().flat_map(|f| f.to_bytes()).collect();
        for offset in 0..clean.len() {
            for bit in 0..8 {
                let mut bytes = clean.clone();
                bytes[offset] ^= 1 << bit;
                drain(&bytes); // must not panic
            }
        }
    });
}
