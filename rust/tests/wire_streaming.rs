//! End-to-end wire-serving suite: the framed streaming service must be
//! a *transparent* transport over the in-process coordinator.
//!
//! The pinning contract (the tentpole's acceptance bar): for the same
//! patient, record and published model, a wire client — over the
//! in-memory duplex or real TCP, at any sample chunking — receives
//! exactly the predictions the in-process [`Coordinator`] computes,
//! window for window, label for label, margin for margin.
//!
//! The robustness contract: a consumer that stops draining is shed
//! (disconnected, its predictions dropped) without perturbing any other
//! session's output; a silent connection is heartbeated and then
//! disconnected as stale; malformed or out-of-order frames close the
//! connection with a reasoned `Shutdown`.

use std::sync::atomic::Ordering::Relaxed;
use std::sync::Arc;
use std::time::{Duration, Instant};

use sparse_hdc_ieeg::config::SystemConfig;
use sparse_hdc_ieeg::coordinator::registry::ModelRegistry;
use sparse_hdc_ieeg::coordinator::server::{Backend, Coordinator, StreamSpec};
use sparse_hdc_ieeg::coordinator::wire::{WireConfig, WireServer};
use sparse_hdc_ieeg::data::metrics::WindowPrediction;
use sparse_hdc_ieeg::data::synth::SynthPatient;
use sparse_hdc_ieeg::hdc::model::ModelBundle;
use sparse_hdc_ieeg::params::{CHANNELS, FRAMES_PER_PREDICTION};
use sparse_hdc_ieeg::testkit::tiny_trained_patient;
use sparse_hdc_ieeg::transport::client::{stream_record, StreamClientConfig, WirePrediction};
use sparse_hdc_ieeg::transport::frame::{write_frame, Frame, ReadOutcome};
use sparse_hdc_ieeg::transport::memory::MemoryTransport;
use sparse_hdc_ieeg::transport::tcp::TcpTransport;
use sparse_hdc_ieeg::transport::Duplex;

/// The in-process ground truth: replay the patient's streaming record
/// through the coordinator and return its per-window predictions.
fn in_process_predictions(
    pid: u32,
    patient: &SynthPatient,
    bundle: &ModelBundle,
) -> Vec<WindowPrediction> {
    let report = Coordinator::new(SystemConfig::default(), Backend::Native)
        .run(vec![StreamSpec {
            session_id: 1,
            patient_id: pid,
            record: patient.records[1].clone(),
            bundle: bundle.clone(),
        }])
        .expect("in-process baseline run");
    report.sessions[0].predictions.clone()
}

/// Window-for-window equality of wire predictions against the
/// in-process baseline (order, label, margin, model version).
fn assert_pinned(
    tag: &str,
    wire: &[WirePrediction],
    baseline: &[WindowPrediction],
    version: u64,
) {
    assert_eq!(wire.len(), baseline.len(), "{tag}: prediction count");
    for (w, b) in wire.iter().zip(baseline) {
        assert_eq!(w.window as usize, b.idx, "{tag}: window order");
        assert_eq!(w.is_ictal, b.is_ictal, "{tag}: label for window {}", b.idx);
        assert_eq!(w.margin, b.margin, "{tag}: margin for window {}", b.idx);
        assert_eq!(w.model_version, version, "{tag}: model version for window {}", b.idx);
    }
}

#[test]
fn memory_wire_predictions_pin_to_in_process() {
    let registry = Arc::new(ModelRegistry::new());
    let mut fixtures = Vec::new();
    for pid in [11u32, 12, 13] {
        let (patient, bundle) = tiny_trained_patient(pid);
        registry.ensure(pid, bundle.clone());
        fixtures.push((pid, patient, bundle));
    }
    let (transport, connector) = MemoryTransport::new();
    let server = WireServer::start(
        Box::new(transport),
        &Backend::Native,
        &SystemConfig::default(),
        registry,
        WireConfig::default(),
    )
    .unwrap();

    // Three concurrent sessions, each chunking its samples differently —
    // the LBP front-end is per-sample, so chunking must not matter.
    let mut clients = Vec::new();
    for ((pid, patient, _), chunk) in fixtures.iter().zip([100usize, 256, 1000]) {
        let conn = connector.connect().unwrap();
        let samples = patient.records[1].samples.clone();
        let pid = *pid;
        clients.push(std::thread::spawn(move || {
            let cfg = StreamClientConfig {
                chunk_samples: chunk,
                ..Default::default()
            };
            stream_record(conn, pid, &samples, &cfg).unwrap()
        }));
    }
    let outcomes: Vec<_> = clients.into_iter().map(|h| h.join().unwrap()).collect();
    let metrics = server.shutdown().unwrap();

    for ((pid, patient, bundle), outcome) in fixtures.iter().zip(&outcomes) {
        assert_eq!(
            outcome.shutdown_reason.as_deref(),
            Some("end of stream"),
            "patient {pid}"
        );
        assert!(
            outcome.send_error.is_none(),
            "patient {pid}: {:?}",
            outcome.send_error
        );
        assert_eq!(outcome.dropped(), 0, "patient {pid}");
        let windows = patient.records[1].samples.len() / (CHANNELS * FRAMES_PER_PREDICTION);
        assert_eq!(outcome.predictions.len(), windows, "patient {pid}");
        let baseline = in_process_predictions(*pid, patient, bundle);
        assert_pinned(
            &format!("patient {pid}"),
            &outcome.predictions,
            &baseline,
            bundle.version,
        );
    }
    assert_eq!(metrics.sessions_started.load(Relaxed), 3, "{}", metrics.summary());
    assert_eq!(metrics.sessions_finished.load(Relaxed), 3, "{}", metrics.summary());
    assert_eq!(metrics.predictions_dropped.load(Relaxed), 0, "{}", metrics.summary());
    assert_eq!(metrics.slow_consumers_shed.load(Relaxed), 0, "{}", metrics.summary());
    assert_eq!(metrics.stale_disconnects.load(Relaxed), 0, "{}", metrics.summary());
    assert_eq!(metrics.protocol_errors.load(Relaxed), 0, "{}", metrics.summary());
}

#[test]
fn tcp_wire_predictions_pin_to_in_process() {
    let (patient, bundle) = tiny_trained_patient(21);
    let registry = Arc::new(ModelRegistry::new());
    registry.ensure(21, bundle.clone());
    let transport = TcpTransport::bind("127.0.0.1:0").unwrap();
    let server = WireServer::start(
        Box::new(transport),
        &Backend::Native,
        &SystemConfig::default(),
        registry,
        WireConfig::default(),
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    let conn = TcpTransport::connect(&addr, Some(Duration::from_secs(5))).unwrap();
    let outcome = stream_record(
        conn,
        21,
        &patient.records[1].samples,
        &StreamClientConfig::default(),
    )
    .unwrap();
    let metrics = server.shutdown().unwrap();

    assert_eq!(outcome.shutdown_reason.as_deref(), Some("end of stream"));
    assert!(outcome.send_error.is_none(), "{:?}", outcome.send_error);
    assert_eq!(outcome.dropped(), 0);
    let baseline = in_process_predictions(21, &patient, &bundle);
    assert_pinned("tcp", &outcome.predictions, &baseline, bundle.version);
    assert_eq!(metrics.sessions_finished.load(Relaxed), 1, "{}", metrics.summary());
}

#[test]
fn overflowing_consumer_is_shed() {
    let (patient, bundle) = tiny_trained_patient(31);
    let registry = Arc::new(ModelRegistry::new());
    registry.ensure(31, bundle);
    let (transport, connector) = MemoryTransport::new();
    let mut cfg = WireConfig::default();
    cfg.conn_queue = 2;
    cfg.staleness = Duration::from_secs(60); // isolate shedding from staleness
    let server = WireServer::start(
        Box::new(transport),
        &Backend::Native,
        &SystemConfig::default(),
        registry,
        cfg,
    )
    .unwrap();

    // Depth-1 pipe with a long write timeout and a client that never
    // reads: the server's writer jams holding two frames (one in the
    // pipe, one in hand), the 2-slot connection queue fills, and the
    // record's remaining windows (28 ≫ 4) force a `try_send` Full — the
    // deterministic shed signal.
    let conn = connector.connect_with(1, Duration::from_secs(30)).unwrap();
    let (reader, mut writer, _peer) = conn.split();
    let samples = patient.records[1].samples.clone();
    let feeder = std::thread::spawn(move || {
        let _ = write_frame(&mut writer, &Frame::Subscribe { patient: 31 });
        for (seq, run) in samples.chunks(256 * CHANNELS).enumerate() {
            let frame = Frame::Samples {
                seq: seq as u64,
                samples: run.to_vec(),
            };
            if write_frame(&mut writer, &frame).is_err() {
                break; // server tore the stream down — expected after the shed
            }
        }
        writer // hold the write half open so EOF cannot race the shed
    });

    let deadline = Instant::now() + Duration::from_secs(10);
    while server.metrics().slow_consumers_shed.load(Relaxed) == 0 {
        assert!(
            Instant::now() < deadline,
            "no shed within 10 s: {}",
            server.metrics().summary()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    drop(reader); // unblock the server's jammed writer (broken pipe)
    let _ = feeder.join();
    let metrics = server.shutdown().unwrap();
    assert_eq!(metrics.slow_consumers_shed.load(Relaxed), 1, "{}", metrics.summary());
    assert!(
        metrics.predictions_dropped.load(Relaxed) >= 1,
        "{}",
        metrics.summary()
    );
    assert_eq!(metrics.sessions_finished.load(Relaxed), 0, "{}", metrics.summary());
}

#[test]
fn stalled_consumer_is_isolated_from_healthy_sessions() {
    let registry = Arc::new(ModelRegistry::new());
    let (healthy_patient, healthy_bundle) = tiny_trained_patient(41);
    let (stalled_patient, stalled_bundle) = tiny_trained_patient(42);
    registry.ensure(41, healthy_bundle.clone());
    registry.ensure(42, stalled_bundle);
    let (transport, connector) = MemoryTransport::new();
    let mut cfg = WireConfig::default();
    cfg.staleness = Duration::from_secs(60); // the stall, not the clock, tears down
    // conn_queue (default 256) exceeds the record's 28 windows, so the
    // healthy session can never see a Full queue even if scheduling
    // starves its writer — only the stalled consumer is torn down.
    let server = WireServer::start(
        Box::new(transport),
        &Backend::Native,
        &SystemConfig::default(),
        registry,
        cfg,
    )
    .unwrap();

    // Stalled: depth-1 pipe, 50 ms write timeout, never reads, never
    // sends its closing Shutdown. The server writer jams on the second
    // prediction, times out, and the connection is torn down mid-stream.
    let stalled = connector
        .connect_with(1, Duration::from_millis(50))
        .unwrap();
    let (mut stalled_reader, mut stalled_writer, _peer) = stalled.split();
    let stalled_samples = stalled_patient.records[1].samples.clone();
    let stalled_feed = std::thread::spawn(move || {
        let _ = write_frame(&mut stalled_writer, &Frame::Subscribe { patient: 42 });
        for (seq, run) in stalled_samples.chunks(256 * CHANNELS).enumerate() {
            let frame = Frame::Samples {
                seq: seq as u64,
                samples: run.to_vec(),
            };
            if write_frame(&mut stalled_writer, &frame).is_err() {
                break; // torn down — expected
            }
        }
        stalled_writer
    });

    // Healthy: a complete client session, concurrent with the stall.
    let healthy_conn = connector.connect().unwrap();
    let healthy_samples = healthy_patient.records[1].samples.clone();
    let healthy = std::thread::spawn(move || {
        stream_record(healthy_conn, 41, &healthy_samples, &StreamClientConfig::default()).unwrap()
    });

    let outcome = healthy.join().unwrap();
    let _ = stalled_feed.join();

    // The healthy session is untouched: complete, orderly, pinned.
    assert_eq!(outcome.shutdown_reason.as_deref(), Some("end of stream"));
    assert!(outcome.send_error.is_none(), "{:?}", outcome.send_error);
    assert_eq!(outcome.dropped(), 0);
    let baseline = in_process_predictions(41, &healthy_patient, &healthy_bundle);
    assert_pinned("healthy", &outcome.predictions, &baseline, healthy_bundle.version);

    // The stalled session was disconnected mid-stream: it can only ever
    // have received the frames that fit its jammed pipe, then EOF.
    stalled_reader
        .get_mut()
        .set_read_timeout(Some(Duration::from_millis(25)))
        .unwrap();
    let windows =
        stalled_patient.records[1].samples.len() / (CHANNELS * FRAMES_PER_PREDICTION);
    let mut stalled_predictions = 0usize;
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        assert!(Instant::now() < deadline, "stalled connection never closed");
        match stalled_reader.read() {
            Ok(ReadOutcome::Frame(Frame::Prediction { .. })) => stalled_predictions += 1,
            Ok(ReadOutcome::Frame(_)) | Ok(ReadOutcome::Idle) => {}
            Ok(ReadOutcome::Eof) | Err(_) => break,
        }
    }
    assert!(
        stalled_predictions < windows,
        "stalled consumer received all {windows} predictions despite never draining"
    );

    let metrics = server.shutdown().unwrap();
    assert_eq!(metrics.sessions_started.load(Relaxed), 2, "{}", metrics.summary());
    assert_eq!(metrics.sessions_finished.load(Relaxed), 1, "{}", metrics.summary());
}

#[test]
fn silent_session_gets_heartbeats_then_a_stale_disconnect() {
    let (_patient, bundle) = tiny_trained_patient(51);
    let registry = Arc::new(ModelRegistry::new());
    registry.ensure(51, bundle);
    let (transport, connector) = MemoryTransport::new();
    let mut cfg = WireConfig::default();
    cfg.heartbeat = Duration::from_millis(50);
    cfg.staleness = Duration::from_millis(400);
    let server = WireServer::start(
        Box::new(transport),
        &Backend::Native,
        &SystemConfig::default(),
        registry,
        cfg,
    )
    .unwrap();

    let conn = connector.connect().unwrap();
    let (mut reader, mut writer, _peer) = conn.split();
    reader
        .get_mut()
        .set_read_timeout(Some(Duration::from_millis(25)))
        .unwrap();
    write_frame(&mut writer, &Frame::Subscribe { patient: 51 }).unwrap();
    // ... then silence: no samples, no heartbeats, nothing.
    let mut heartbeats = 0u64;
    let mut reason = None;
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline && reason.is_none() {
        match reader.read().unwrap() {
            ReadOutcome::Frame(Frame::Heartbeat { .. }) => heartbeats += 1,
            ReadOutcome::Frame(Frame::Shutdown { reason: r }) => reason = Some(r),
            ReadOutcome::Frame(f) => panic!("unexpected frame: {}", f.kind_name()),
            ReadOutcome::Idle => {}
            ReadOutcome::Eof => break,
        }
    }
    let metrics = server.shutdown().unwrap();
    let reason = reason.expect("server must close a silent session with a reasoned Shutdown");
    assert!(reason.contains("stale"), "unexpected reason: {reason}");
    assert!(heartbeats >= 1, "the writer must heartbeat through idle gaps");
    assert_eq!(metrics.stale_disconnects.load(Relaxed), 1, "{}", metrics.summary());
}

#[test]
fn reconnecting_client_resubscribes_and_resumes_cleanly() {
    // The reconnect contract: sessions are per-connection. A client that
    // loses its connection mid-stream and dials back in re-`Subscribe`s
    // the same patient and starts a fresh sequence from seq 0 — the
    // server replays the full record with pinned predictions, exactly as
    // if the first attempt never happened. A reconnect that instead
    // tries to resume mid-sequence is closed with a reasoned `Shutdown`
    // (never silence, never corrupted windows). This is the behaviour
    // the fleet dispatcher's re-lease path builds on.
    let (patient, bundle) = tiny_trained_patient(71);
    let registry = Arc::new(ModelRegistry::new());
    registry.ensure(71, bundle.clone());
    let (transport, connector) = MemoryTransport::new();
    let server = WireServer::start(
        Box::new(transport),
        &Backend::Native,
        &SystemConfig::default(),
        registry,
        WireConfig::default(),
    )
    .unwrap();
    let samples = patient.records[1].samples.clone();

    // Attempt 1: subscribe, stream a 3-window prefix, then vanish
    // (connection dropped without a closing Shutdown — a client crash).
    let conn = connector.connect().unwrap();
    let (reader, mut writer, _peer) = conn.split();
    write_frame(&mut writer, &Frame::Subscribe { patient: 71 }).unwrap();
    let prefix = &samples[..CHANNELS * FRAMES_PER_PREDICTION * 3];
    write_frame(
        &mut writer,
        &Frame::Samples {
            seq: 0,
            samples: prefix.to_vec(),
        },
    )
    .unwrap();
    drop(writer);
    drop(reader);

    // Attempt 2: reconnect, re-Subscribe the same patient, stream the
    // whole record from seq 0 — orderly end, pinned window-for-window.
    let conn = connector.connect().unwrap();
    let outcome = stream_record(conn, 71, &samples, &StreamClientConfig::default()).unwrap();
    assert_eq!(outcome.shutdown_reason.as_deref(), Some("end of stream"));
    assert!(outcome.send_error.is_none(), "{:?}", outcome.send_error);
    assert_eq!(outcome.dropped(), 0);
    let baseline = in_process_predictions(71, &patient, &bundle);
    assert_pinned("reconnect", &outcome.predictions, &baseline, bundle.version);

    // A reconnect that tries to *continue* the old sequence instead of
    // restarting gets the reasoned seq-gap Shutdown.
    let r = expect_shutdown(
        connector.connect().unwrap(),
        vec![
            Frame::Subscribe { patient: 71 },
            Frame::Samples {
                seq: 3,
                samples: vec![0.0f32; CHANNELS],
            },
        ],
    );
    assert!(r.contains("seq 3, expected 0"), "{r}");

    let metrics = server.shutdown().unwrap();
    assert_eq!(metrics.sessions_started.load(Relaxed), 3, "{}", metrics.summary());
    assert_eq!(metrics.sessions_finished.load(Relaxed), 1, "{}", metrics.summary());
    assert_eq!(metrics.protocol_errors.load(Relaxed), 1, "{}", metrics.summary());
}

/// Send `frames`, then read until the server's reasoned `Shutdown`.
fn expect_shutdown(conn: Duplex, frames: Vec<Frame>) -> String {
    let (mut reader, mut writer, _peer) = conn.split();
    reader
        .get_mut()
        .set_read_timeout(Some(Duration::from_millis(25)))
        .unwrap();
    for f in &frames {
        write_frame(&mut writer, f).unwrap();
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline {
        match reader.read().expect("readable until the server's Shutdown") {
            ReadOutcome::Frame(Frame::Shutdown { reason }) => return reason,
            ReadOutcome::Frame(_) | ReadOutcome::Idle => {}
            ReadOutcome::Eof => panic!("EOF before the Shutdown frame"),
        }
    }
    panic!("no Shutdown within 10 s");
}

#[test]
fn protocol_errors_close_the_connection_with_a_reason() {
    let (_patient, bundle) = tiny_trained_patient(61);
    let registry = Arc::new(ModelRegistry::new());
    registry.ensure(61, bundle);
    let (transport, connector) = MemoryTransport::new();
    let server = WireServer::start(
        Box::new(transport),
        &Backend::Native,
        &SystemConfig::default(),
        registry,
        WireConfig::default(),
    )
    .unwrap();

    let one_sample = vec![0.0f32; CHANNELS];

    let r = expect_shutdown(
        connector.connect().unwrap(),
        vec![Frame::Samples {
            seq: 0,
            samples: one_sample.clone(),
        }],
    );
    assert!(r.contains("Samples before Subscribe"), "{r}");

    let r = expect_shutdown(
        connector.connect().unwrap(),
        vec![Frame::Subscribe { patient: 999 }],
    );
    assert!(r.contains("no model published for patient 999"), "{r}");

    let r = expect_shutdown(
        connector.connect().unwrap(),
        vec![
            Frame::Subscribe { patient: 61 },
            Frame::Samples {
                seq: 5,
                samples: one_sample.clone(),
            },
        ],
    );
    assert!(r.contains("seq 5, expected 0"), "{r}");

    let r = expect_shutdown(
        connector.connect().unwrap(),
        vec![
            Frame::Subscribe { patient: 61 },
            Frame::Subscribe { patient: 61 },
        ],
    );
    assert!(r.contains("duplicate Subscribe"), "{r}");

    let r = expect_shutdown(
        connector.connect().unwrap(),
        vec![
            Frame::Subscribe { patient: 61 },
            Frame::Prediction {
                window: 0,
                is_ictal: false,
                margin: 0,
                model_version: 1,
            },
        ],
    );
    assert!(r.contains("Prediction"), "{r}");

    let metrics = server.shutdown().unwrap();
    assert_eq!(metrics.protocol_errors.load(Relaxed), 5, "{}", metrics.summary());
    assert_eq!(metrics.sessions_finished.load(Relaxed), 0, "{}", metrics.summary());
}
