//! Offline **stub** of the `xla` (xla-rs) API surface that
//! `sparse-hdc-ieeg`'s `pjrt` feature compiles against.
//!
//! The offline build environment has no network and no PJRT plugin, so
//! this crate exists to keep the `--features pjrt` code path
//! *type-checked* (CI builds it) while every entry point that would need
//! a real PJRT client fails at runtime with an actionable message.
//!
//! To actually execute the AOT HLO artifacts, replace this crate with the
//! real `xla` crate (<https://github.com/LaurentMazare/xla-rs>), either by
//! vendoring it at `rust/vendor/xla` or with a `[patch]` entry in the
//! workspace manifest. The API below intentionally mirrors the subset
//! `runtime::pjrt` uses: `PjRtClient`, `HloModuleProto`, `XlaComputation`,
//! `PjRtLoadedExecutable`, `Literal`.

use std::fmt;

/// Error type mirroring xla-rs's displayable error.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "xla stub: {what} needs the real `xla` crate (PJRT runtime); this build vendors an \
         offline stub — replace rust/vendor/xla with xla-rs (or use the native backend, which \
         needs no artifacts). See README §PJRT."
    ))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu()"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile()"))
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file()"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable (stub: execution always fails).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute()"))
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync()"))
    }
}

/// A host literal. The stub accepts construction/reshape (cheap, host-only
/// in the real crate too) so table building type-checks; data access fails.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(unavailable("Literal::to_tuple2()"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec()"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_points_fail_actionably() {
        let e = PjRtClient::cpu().err().expect("stub must not succeed");
        let msg = e.to_string();
        assert!(msg.contains("xla stub"), "{msg}");
        assert!(msg.contains("native backend"), "{msg}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }

    #[test]
    fn literal_construction_is_permitted() {
        let lit = Literal::vec1(&[1i32, 2, 3]).reshape(&[3]).unwrap();
        assert!(lit.to_vec::<i32>().is_err());
    }
}
