#!/usr/bin/env bash
# Promote a CI run's measured perf artifacts to the committed baselines
# (ROADMAP "Perf trajectory" item): benchkit/v1 bench JSON and, when
# present, the wire job's loadgen/v1 report.
#
# Usage:
#   1. Download the `bench-trajectory-json` (and optionally
#      `loadgen-report`) artifact from a CI run on the target commit —
#      or run the benches locally:
#      BENCH_FAST=1 BENCH_JSON=$PWD/BENCH_encoder.current.json \
#          cargo bench --bench bench_encoder
#      BENCH_FAST=1 BENCH_JSON=$PWD/BENCH_am.current.json \
#          cargo bench --bench bench_am).
#   2. ./scripts/promote-bench-baselines.sh [artifact-dir]
#   3. Review the diff and commit — `repro bench-diff` / `repro
#      loadgen-diff` then gate against real numbers. Both refuse to run
#      against a never-promoted stub baseline, so this promotion is not
#      optional once the gates are in CI.
set -euo pipefail

src="${1:-.}"
root="$(cd "$(dirname "$0")/.." && pwd)"

promote() {
    local current="$src/$1.current.json" baseline="$root/$1.json"
    if [[ ! -f "$current" ]]; then
        echo "skip: $current not found" >&2
        return
    fi
    if ! grep -q '"records": \[' "$current"; then
        echo "refuse: $current does not look like a benchkit/v1 document" >&2
        exit 1
    fi
    cp "$current" "$baseline"
    echo "promoted $current -> $baseline"
}

promote BENCH_encoder
promote BENCH_am
promote BENCH_registry

# Loadgen reports (sessions > 0 distinguishes a real report from the
# committed stub): the wire job's single-process report and the fleet
# job's 2-shard dispatcher report.
promote_loadgen() {
    local current="$src/$1.current.json" baseline="$root/$2.json"
    if [[ ! -f "$current" ]]; then
        echo "skip: $current not found" >&2
        return
    fi
    if ! grep -q '"schema": "loadgen/v1"' "$current"; then
        echo "refuse: $current does not look like a loadgen/v1 report" >&2
        exit 1
    fi
    if grep -Eq '"sessions": 0[,}[:space:]]' "$current"; then
        echo "refuse: $current is itself a stub (0 sessions)" >&2
        exit 1
    fi
    cp "$current" "$baseline"
    echo "promoted $current -> $baseline"
}

promote_loadgen loadgen LOADGEN_wire
promote_loadgen loadgen_fleet LOADGEN_fleet

echo "done — review with: git diff BENCH_encoder.json BENCH_am.json BENCH_registry.json LOADGEN_wire.json LOADGEN_fleet.json"
