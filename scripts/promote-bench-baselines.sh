#!/usr/bin/env bash
# Promote a CI run's bench-trajectory-json artifact to the committed
# perf baselines (ROADMAP "Perf trajectory" item).
#
# Usage:
#   1. Download the `bench-trajectory-json` artifact from a CI run on the
#      target commit (or run the benches locally:
#      BENCH_FAST=1 BENCH_JSON=$PWD/BENCH_encoder.current.json \
#          cargo bench --bench bench_encoder
#      BENCH_FAST=1 BENCH_JSON=$PWD/BENCH_am.current.json \
#          cargo bench --bench bench_am).
#   2. ./scripts/promote-bench-baselines.sh [artifact-dir]
#   3. Review the diff and commit — `repro bench-diff` then gates kernel/*
#      medians against real numbers instead of the empty stubs.
set -euo pipefail

src="${1:-.}"
root="$(cd "$(dirname "$0")/.." && pwd)"

promote() {
    local current="$src/$1.current.json" baseline="$root/$1.json"
    if [[ ! -f "$current" ]]; then
        echo "skip: $current not found" >&2
        return
    fi
    if ! grep -q '"records": \[' "$current"; then
        echo "refuse: $current does not look like a benchkit/v1 document" >&2
        exit 1
    fi
    cp "$current" "$baseline"
    echo "promoted $current -> $baseline"
}

promote BENCH_encoder
promote BENCH_am

echo "done — review with: git diff BENCH_encoder.json BENCH_am.json"
